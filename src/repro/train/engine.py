"""Unified step engine: one `build_train_step` for every exchange mechanism.

The paper's contribution is a comparison of *synchronization mechanisms*
(Section 3): fully-synchronous all-reduce, prediction exchange, and Anil et
al.'s checkpoint exchange. Each mechanism used to live in its own step
factory, duplicating the schedule/optimizer/microbatch plumbing and drifting
apart (microbatching and the ``trainable`` mask only worked for some of
them). This module makes the mechanism a first-class pluggable object:

    strategy = resolve_strategy(codist)          # or an explicit instance
    bundle   = build_train_step(model, tc, codist, strategy, trainable)
    state    = strategy.init_state(model, tc, key, opt_init, example_batch)
    state, metrics, plan = bundle.apply(state, batch, k)

``build_train_step`` threads the shared pieces through **every** strategy
exactly once: LR / weight-decay / label-smoothing / alpha schedules evaluated
from ``state.step``, ``_grads_with_metrics`` microbatched gradient
accumulation, and the ``opt_update(..., trainable)`` optimizer call. A
strategy only supplies what genuinely differs:

  * ``plan(step)``        — host-side schedule: which compiled variant runs
                            and whether an exchange (communication) happens;
  * ``distill_targets``   — the distillation-target kwargs for
                            ``codist_loss`` (live logits / stale-replica
                            pairwise / previous-step logits);
  * ``loss``              — the traced loss (default template uses
                            ``distill_targets``; shard_map overrides it);
  * ``post_update``       — cross-step strategy state (stale replicas, the
                            pipelined peer buffer);
  * ``comm_bytes``        — Section-3 accounting: bytes crossing the slow
                            links per exchange event.

Concrete strategies:

  AllReduce             baseline: gradient sync every step (single model)
  PredictionExchange    Algorithm 1, coordinated sampling, logits exchange
  CheckpointExchange    Anil et al. (arXiv:1804.03235): distill against the
                        stale replica set, params exchanged every T steps
  PipelinedPredictions  beyond-paper: previous exchange's logits as targets,
                        removing the per-step sync point
  ShardMapCompressed    beyond-paper: explicit ``shard_map`` over the "pod"
                        axis so only the compressed wire crosses pods

The legacy step factories (``make_codist_step`` et al.) were removed after
every caller migrated here; this module is the only way to build steps.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import CodistConfig, TrainConfig
from repro.core import codistillation as cd
from repro.core import comm_model as cm
from repro.core import schedules as sched
from repro.core.exchange import StepPlan
from repro.optim import make_optimizer
from repro.train.state import (CodistState, TrainState, init_codist_state,
                               init_peer_state, init_train_state)

PyTree = Any


# ----------------------------------------------------------------------------
# schedule bundle (shared by every strategy)
# ----------------------------------------------------------------------------

class Schedules(NamedTuple):
    lr: Callable
    wd: Callable
    ls: Callable
    alpha: Callable


def make_schedules(tc: TrainConfig, codist: Optional[CodistConfig] = None):
    lr_fn = sched.make_lr_fn(tc.lr_schedule, tc.lr, tc.total_steps,
                             tc.warmup_steps, tc.step_milestones, tc.step_decay)
    if tc.weight_decay_schedule:
        values = tuple(tc.weight_decay_schedule)
        miles = tc.step_milestones[: len(values) - 1]
        wd_fn = lambda s: sched.scheduled_weight_decay(s, tc.total_steps,
                                                       values, miles)
    else:
        wd_fn = lambda s: sched.constant_weight_decay(s, tc.weight_decay)
    if tc.label_smoothing_decay:
        ls_fn = lambda s: sched.decayed_label_smoothing(s, tc.total_steps,
                                                        tc.label_smoothing)
    else:
        ls_fn = lambda s: jnp.asarray(tc.label_smoothing, jnp.float32)
    if codist is not None:
        alpha_fn = lambda s: sched.alpha_schedule(
            s, codist.alpha0, codist.alpha_growth, codist.steps_per_epoch,
            codist.burn_in_steps)
    else:
        alpha_fn = lambda s: jnp.zeros((), jnp.float32)
    return lr_fn, wd_fn, ls_fn, alpha_fn


# ----------------------------------------------------------------------------
# shared forward / gradient-accumulation helpers
# ----------------------------------------------------------------------------

def _task_forward(model, params: PyTree, batch: Dict, remat: bool):
    """Unified forward over LM / enc-dec / conv models."""
    if hasattr(model.cfg, "kind"):  # ConvConfig
        return model.forward(params, batch)
    return model.forward(params, batch, remat=remat)


def _stacked_forward(model, stacked_params: PyTree, batch_all: Dict,
                     remat: bool):
    """vmap over the model axis: batch_all arrays carry a leading n axis."""
    def one(params, batch):
        return _task_forward(model, params, batch, remat)
    return jax.vmap(one)(stacked_params, batch_all)


def _grads_metrics_aux(loss_fn, params: PyTree, batch: Dict, k: int,
                       accum_dtype=jnp.float32):
    """Gradients of ``loss_fn(params, batch) -> (loss, (metrics, aux))``.

    k>1 enables microbatched gradient accumulation: every batch leaf carries a
    leading (k, B/k, ...) axis and a lax.scan accumulates fp32 grads — the
    production memory lever for the biggest configs (per-layer activations
    saved for backward scale with B/k, not B). ``metrics`` are averaged over
    microbatches; ``aux`` (optional pytree, e.g. the pipelined peer logits) is
    STACKED with a leading k axis so per-example tensors survive accumulation.
    """
    if k <= 1:
        (_, (metrics, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics, aux

    m_shape, _ = jax.eval_shape(
        lambda p, b: loss_fn(p, b)[1], params,
        jax.tree.map(lambda x: x[0], batch))
    m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)

    def body(carry, mb):
        g_acc, m_acc = carry
        (_, (m, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, gg: a + gg.astype(accum_dtype) / k,
                             g_acc, g)
        m_acc = jax.tree.map(lambda a, mm: a + mm / k, m_acc, m)
        return (g_acc, m_acc), aux

    (grads, metrics), aux = jax.lax.scan(body, (g0, m0), batch)
    return grads, metrics, aux


def _grads_with_metrics(loss_fn, params: PyTree, batch: Dict, k: int,
                        accum_dtype=jnp.float32):
    """Legacy aux-free spelling: ``loss_fn -> (loss, metrics)``."""
    def wrapped(p, b):
        total, metrics = loss_fn(p, b)
        return total, (metrics, None)
    grads, metrics, _ = _grads_metrics_aux(wrapped, params, batch, k,
                                           accum_dtype)
    return grads, metrics


def _param_bits(params: PyTree, n: int = 1) -> float:
    """Bits of one model's parameter vector (stacked trees carry n models)."""
    total = sum(x.size * jnp.dtype(x.dtype).itemsize * 8
                for x in jax.tree.leaves(params))
    return total / max(1, n)


def _plain_task_metrics(codist, logits_all, batch, ls, fused):
    """Stacked task-only loss (the prediction off-step / alpha=0 shape)."""
    task = jax.vmap(
        lambda lg, lb, m: cd.cross_entropy(lg, lb, ls, m, fused=fused)
    )(logits_all, batch["labels"],
      batch.get("mask", jnp.ones(batch["labels"].shape, jnp.float32)))
    total = jnp.mean(task)
    metrics = {"loss": total, "task_loss": total,
               "distill_loss": jnp.zeros(()),
               "task_loss_per_model": task,
               "distill_loss_per_model": jnp.zeros_like(task),
               "alpha": jnp.zeros(())}
    return total, metrics


# ----------------------------------------------------------------------------
# the strategy protocol
# ----------------------------------------------------------------------------

class ExchangeStrategy:
    """Pluggable Section-3 synchronization mechanism.

    Host-side API (loop / StepBundle): ``init_state``, ``ensure_state``,
    ``plan``, ``variant_for``, ``host_exchange``, ``comm_bytes``,
    ``make_eval``. Traced API (inside the compiled step): ``prepare``,
    ``distill_targets``, ``loss``, ``post_update``. The default ``loss``
    template covers every stacked-logits mechanism via ``distill_targets``;
    strategies with a structurally different loss (pipelined replay,
    shard_map) override it.
    """

    name = "base"
    variants: Tuple[str, ...] = ("on",)
    stacked = True  # CodistState with leading n axis (vs single TrainState)

    def __init__(self, codist: Optional[CodistConfig] = None):
        self.codist = codist

    # ---- host side ---------------------------------------------------------
    def init_state(self, model, tc: TrainConfig, key, opt_init,
                   example_batch: Optional[Dict] = None):
        return init_codist_state(model, key, self.codist.n_models, opt_init)

    def ensure_state(self, state, model, tc: TrainConfig,
                     example_batch: Optional[Dict] = None):
        """Repair strategy-specific state on a user-supplied ``state``."""
        return state

    def plan(self, step: int) -> StepPlan:
        raise NotImplementedError

    def variant_for(self, plan: StepPlan) -> str:
        return "on"

    def host_exchange(self, state):
        """Host-side exchange action (checkpoint mode refreshes the stale
        replicas); the default mechanisms exchange inside the compiled step."""
        return state

    def comm_bytes(self, model, state, batch_all: Dict,
                   microbatch: int = 0) -> float:
        """Bytes crossing the slow (cross-pod) links per exchange EVENT."""
        return 0.0

    def make_eval(self, model, tc: TrainConfig) -> Callable:
        return make_codist_eval_step(model, tc)

    # ---- traced (inside the compiled step) ---------------------------------
    def prepare(self, state, batch_all: Dict, k: int):
        """Scan operand for ``_grads_metrics_aux``: microbatch axis moves in
        front of the stacked model axis ((n, k, B/k, ...) -> (k, n, ...))."""
        if k > 1:
            return jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch_all)
        return batch_all

    def distill_targets(self, model, tc: TrainConfig, state, batch: Dict,
                        logits_all) -> Dict:
        """kwargs for ``codist_loss`` selecting the distillation targets."""
        return {}

    def loss(self, model, tc: TrainConfig, sch: Schedules, state, params,
             batch: Dict, variant: str):
        """Return ``(total, metrics, aux)`` for one (micro)batch."""
        logits_all, aux_all = _stacked_forward(model, params, batch, tc.remat)
        if variant == "on":
            total, metrics = cd.codist_loss(
                self.codist, logits_all, batch["labels"],
                sch.alpha(state.step), sch.ls(state.step), batch.get("mask"),
                fused=tc.fused_losses,
                **self.distill_targets(model, tc, state, batch, logits_all))
        else:
            total, metrics = _plain_task_metrics(
                self.codist, logits_all, batch, sch.ls(state.step),
                tc.fused_losses)
        total = total + jnp.mean(aux_all)
        metrics["aux_loss"] = jnp.mean(aux_all)
        metrics["accuracy"] = jnp.mean(jax.vmap(cd.accuracy)(
            logits_all, batch["labels"]))
        return total, metrics, None

    def post_update(self, state, params, opt, batch_all: Dict, aux, k: int):
        return CodistState(params, opt, state.step + 1, state.stale,
                           state.peer)


# ----------------------------------------------------------------------------
# concrete strategies
# ----------------------------------------------------------------------------

class AllReduce(ExchangeStrategy):
    """Standard data-parallel baseline: the gradient all-reduce crosses the
    pod links every step (C_AR = 2 * b_model bits/iter, Section 3)."""

    name = "all_reduce"
    stacked = False

    def init_state(self, model, tc, key, opt_init, example_batch=None):
        return init_train_state(model, key, opt_init)

    def plan(self, step: int) -> StepPlan:
        return StepPlan(distill=False, exchange=True)

    def comm_bytes(self, model, state, batch_all, microbatch=0) -> float:
        return 2.0 * _param_bits(state.params) / 8.0

    def make_eval(self, model, tc):
        return make_eval_step(model, tc)

    def loss(self, model, tc, sch, state, params, batch, variant):
        logits, aux = _task_forward(model, params, batch, tc.remat)
        task = cd.cross_entropy(logits, batch["labels"], sch.ls(state.step),
                                batch.get("mask"), fused=tc.fused_losses)
        metrics = {"loss": task + aux, "task_loss": task, "aux_loss": aux,
                   "accuracy": cd.accuracy(logits, batch["labels"],
                                           batch.get("mask"))}
        return task + aux, metrics, None

    def prepare(self, state, batch_all, k):
        # single-model batches already carry the (k, B/k, ...) layout
        return batch_all

    def post_update(self, state, params, opt, batch_all, aux, k):
        return TrainState(params, opt, state.step + 1)


class PredictionExchange(ExchangeStrategy):
    """Algorithm 1 with coordinated sampling: on exchange steps the stacked
    logits are the distillation targets (the cross-pod logits collective);
    off steps compile a separate variant that omits the distillation term —
    and hence the collective — entirely (Section 3's periodic exchange)."""

    name = "prediction"
    variants = ("on", "off")

    def plan(self, step: int) -> StepPlan:
        return StepPlan.for_step(replace(self.codist, mode="predictions"),
                                 step)

    def variant_for(self, plan: StepPlan) -> str:
        return "on" if plan.distill else "off"

    def comm_bytes(self, model, state, batch_all, microbatch=0) -> float:
        cfg = self.codist
        try:
            labels = batch_all["labels"]
            n = cfg.n_models
            mcfg = getattr(model, "cfg", None)
            if labels.ndim >= 3:  # LM: (n, [k,] B, S)
                seq = labels.shape[-1]
                samples = labels.size // (n * seq)
                b_pred = cm.prediction_bits_lm(mcfg, seq, 32, cfg.compression,
                                               cfg.topk, cfg.subsample)
            else:                 # classifier: (n, B)
                samples = labels.size // n
                b_pred = cm.prediction_bits_classifier(mcfg.num_classes)
            return (n - 1) * b_pred * samples / 8.0
        except (KeyError, AttributeError, TypeError):
            # model without Section-3 accounting metadata (e.g. a custom
            # cfg): report 0 rather than refuse to train
            return 0.0


class CheckpointExchange(PredictionExchange):
    """Anil et al.'s variant: every step each model draws its OWN batch and
    distills against the stale replicas' predictions on it (n-1 extra
    gradient-free forwards); every T steps the host refreshes ``state.stale``
    via ``refresh_stale`` (the cross-pod parameter all-gather)."""

    name = "checkpoint"
    variants = ("on",)

    def init_state(self, model, tc, key, opt_init, example_batch=None):
        return init_codist_state(model, key, self.codist.n_models, opt_init,
                                 with_stale=True)

    def ensure_state(self, state, model, tc, example_batch=None):
        if state.stale is None:  # user-supplied state without stale replicas
            return state._replace(stale=jax.tree.map(jnp.array, state.params))
        return state

    def plan(self, step: int) -> StepPlan:
        # distill EVERY step against the stale replicas (even during burn-in,
        # where alpha is 0); exchange every T per the config-driven schedule
        p = StepPlan.for_step(replace(self.codist, mode="checkpoints"), step)
        return StepPlan(True, p.exchange)

    def variant_for(self, plan: StepPlan) -> str:
        return "on"

    def host_exchange(self, state):
        return refresh_stale(state)

    def comm_bytes(self, model, state, batch_all, microbatch=0) -> float:
        n = self.codist.n_models
        return (n - 1) * _param_bits(state.params, n) / 8.0

    def distill_targets(self, model, tc, state, batch, logits_all):
        # peer_pairwise[i, j] = stale_j(x_i); gradient-free, recomputed per
        # microbatch so gradient accumulation stays exact
        def stale_on_batch(batch_i):
            return jax.vmap(
                lambda sp: _task_forward(model, sp, batch_i, tc.remat)[0]
            )(state.stale)
        peer_pairwise = jax.lax.stop_gradient(
            jax.vmap(stale_on_batch)(batch))     # (n_batch=i, n_model=j, ...)
        return {"peer_pairwise": peer_pairwise}


class PipelinedPredictions(ExchangeStrategy):
    """Beyond-paper: distill against the PREVIOUS exchange's peer logits,
    replaying the previous (coordinated) batch for the distill term. The
    logits collective of step k-1 overlaps with step k's compute, removing
    the sync point the paper flags for prediction exchange.

    ``state.peer = {"batch": prev batch_all, "logits": prev logits_all,
    "valid": bool}`` — with microbatching both carry the (n, k, B/k, ...)
    layout so the replay pairs microbatch m with its own stale logits.
    """

    name = "pipelined"

    def init_state(self, model, tc, key, opt_init, example_batch=None):
        state = init_codist_state(model, key, self.codist.n_models, opt_init)
        return self.ensure_state(state, model, tc, example_batch)

    def ensure_state(self, state, model, tc, example_batch=None):
        if state.peer is not None or example_batch is None:
            return state
        n = self.codist.n_models
        k = tc.microbatch

        def slice0(x):  # model 0 (and microbatch 0 when microbatched)
            return x[0][0] if k > 1 else x[0]
        logits_shape = jax.eval_shape(
            lambda p, b: _task_forward(model, p, b, False)[0],
            jax.tree.map(lambda x: x[0], state.params),
            jax.tree.map(slice0, example_batch)).shape
        lead = (n, k) if k > 1 else (n,)
        return state._replace(peer=init_peer_state(example_batch,
                                                   lead + logits_shape))

    def plan(self, step: int) -> StepPlan:
        # the (stale) logits collective overlaps every step
        return StepPlan(True, True)

    def comm_bytes(self, model, state, batch_all, microbatch=0) -> float:
        return PredictionExchange.comm_bytes(self, model, state, batch_all,
                                             microbatch)

    def prepare(self, state, batch_all, k):
        operand = {"batch": batch_all, "peer_batch": state.peer["batch"],
                   "peer_logits": state.peer["logits"]}
        if k > 1:
            operand = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), operand)
        return operand

    def loss(self, model, tc, sch, state, params, operand, variant):
        batch = operand["batch"]
        peer_batch = operand["peer_batch"]
        codist = self.codist
        logits_all, aux_all = _stacked_forward(model, params, batch, tc.remat)
        task = jax.vmap(
            lambda lg, lb, m: cd.cross_entropy(lg, lb, sch.ls(state.step), m,
                                               fused=tc.fused_losses)
        )(logits_all, batch["labels"],
          batch.get("mask", jnp.ones(batch["labels"].shape, jnp.float32)))
        # replay forward on the previous batch for the distillation term
        replay_logits, _ = _stacked_forward(model, params, peer_batch,
                                            tc.remat)
        _, dmetrics = cd.codist_loss(
            codist, replay_logits, peer_batch["labels"],
            sch.alpha(state.step), 0.0, peer_batch.get("mask"),
            peer_logits_all=operand["peer_logits"], fused=tc.fused_losses)
        dist = dmetrics["distill_loss_per_model"]
        alpha = sch.alpha(state.step) * state.peer["valid"].astype(jnp.float32)
        total = jnp.mean(task + alpha * dist) + jnp.mean(aux_all)
        metrics = {"loss": total, "task_loss": jnp.mean(task),
                   "distill_loss": jnp.mean(dist), "alpha": alpha,
                   "aux_loss": jnp.mean(aux_all),
                   "accuracy": jnp.mean(jax.vmap(cd.accuracy)(
                       logits_all, batch["labels"]))}
        return total, metrics, jax.lax.stop_gradient(logits_all)

    def post_update(self, state, params, opt, batch_all, aux, k):
        logits = aux
        if k > 1:  # scan stacked (k, n, B/k, ...) -> stored (n, k, B/k, ...)
            logits = jnp.swapaxes(logits, 0, 1)
        new_peer = {"batch": batch_all,
                    "logits": logits.astype(state.peer["logits"].dtype),
                    "valid": jnp.ones((), jnp.bool_)}
        return CodistState(params, opt, state.step + 1, state.stale, new_peer)


class AsyncPrediction(ExchangeStrategy):
    """Single-peer view of the prediction exchange for the async runtime.

    The synchronous ``PredictionExchange`` computes every model's forward in
    one vmapped step; in ``repro.runtime`` each peer runs on its OWN step
    clock, so a step sees only this peer's params and the distillation
    targets arrive from the host (``runtime.mailbox`` payloads posted by
    peers on their own clocks). The operand is::

        {"batch": <single-model batch>,
         "peer_wire":      compressed-wire pytree (``compress_targets``,
                           producer side), every leaf stacked (P, ...);
                           zero-filled slots for absent peers
         "peer_weight":    (P,)  1.0 accepted / 0.0 dropped-or-missing
         "peer_staleness": (P,)  receiver_step - sender_step}

    The traced loss is ``(task + alpha * dist + aux) / n_slots`` — exactly
    this peer's share of ``codist_loss``'s mean over n models (every other
    model's term is a constant w.r.t. this peer's params), so with fresh
    same-step targets the gradient, and hence the whole trajectory, matches
    the synchronous engine (pinned by ``tests/test_runtime.py``). The weight
    vector implements the staleness-bound drop policy: dropped peers
    contribute nothing, and when every payload is dropped the distillation
    term (and alpha) vanishes — the step degrades to plain task training
    instead of blocking, which is the fault-tolerance argument of Anil et
    al. (arXiv:1804.03235). Metrics report the UNSCALED task/distill terms
    plus the measured staleness of the targets actually used.
    """

    name = "async_prediction"
    variants = ("on", "off")
    stacked = False

    def __init__(self, codist: CodistConfig, n_slots: Optional[int] = None):
        super().__init__(codist)
        # the divisor of the codist mean AND 1 + number of target slots;
        # fixed at build time so elastic membership keeps shapes static
        self.n_slots = max(2, n_slots or codist.n_models)

    def init_state(self, model, tc, key, opt_init, example_batch=None):
        return init_train_state(model, key, opt_init)

    def plan(self, step: int) -> StepPlan:
        # standalone use mirrors the synchronous prediction schedule; the
        # AsyncScheduler drives variants directly from mailbox availability
        return StepPlan.for_step(replace(self.codist, mode="predictions"),
                                 step)

    def variant_for(self, plan: StepPlan) -> str:
        return "on" if plan.distill else "off"

    def make_eval(self, model, tc):
        return make_eval_step(model, tc)

    def comm_bytes(self, model, state, operand, microbatch=0) -> float:
        cfg = self.codist
        try:
            batch = operand["batch"] if "batch" in operand else operand
            labels = batch["labels"]
            seq = labels.shape[-1]
            samples = labels.size // seq
            b_pred = cm.prediction_bits_lm(model.cfg, seq, 32,
                                           cfg.compression, cfg.topk,
                                           cfg.subsample)
            return (self.n_slots - 1) * b_pred * samples / 8.0
        except (KeyError, AttributeError, TypeError):
            return 0.0

    def prepare(self, state, operand, k):
        if k <= 1:
            return operand
        # batch leaves already carry the (k, B/k, ...) layout (single model);
        # wire leaves arrive as (P, k, ...) and scalars-per-peer are tiled so
        # the gradient-accumulation scan can slice a k axis off every leaf
        return {"batch": operand["batch"],
                "peer_wire": jax.tree.map(
                    lambda x: jnp.swapaxes(x, 0, 1), operand["peer_wire"]),
                "peer_weight": jnp.broadcast_to(
                    operand["peer_weight"],
                    (k,) + operand["peer_weight"].shape),
                "peer_staleness": jnp.broadcast_to(
                    operand["peer_staleness"],
                    (k,) + operand["peer_staleness"].shape)}

    def loss(self, model, tc, sch, state, params, operand, variant):
        batch = operand["batch"] if "batch" in operand else operand
        logits, aux = _task_forward(model, params, batch, tc.remat)
        mask = batch.get("mask")
        task = cd.cross_entropy(logits, batch["labels"], sch.ls(state.step),
                                mask, fused=tc.fused_losses)
        acc = cd.accuracy(logits, batch["labels"], mask)
        n = self.n_slots
        if variant != "on":
            total = (task + aux) / n
            metrics = {"loss": total, "task_loss": task,
                       "distill_loss": jnp.zeros(()), "aux_loss": aux,
                       "alpha": jnp.zeros(()), "accuracy": acc,
                       "staleness": jnp.zeros(()),
                       "peer_weight": jnp.zeros(())}
            return total, metrics, None
        wires = operand["peer_wire"]  # host-provided constants: no gradient
        w = operand["peer_weight"].astype(jnp.float32)
        st = operand["peer_staleness"].astype(jnp.float32)
        ds = []
        for j in range(jax.tree.leaves(wires)[0].shape[0]):
            wire = jax.tree.map(lambda x: x[j], wires)
            ds.append(cd.distill_vs_compressed(self.codist, logits, wire,
                                               mask, fused=tc.fused_losses))
        d = jnp.stack(ds)
        wsum = jnp.sum(w)
        denom = jnp.maximum(wsum, 1.0)   # == n-1 with a full fresh mailbox
        dist = jnp.sum(w * d) / denom
        stale = jnp.sum(w * st) / denom
        alpha = sch.alpha(state.step) * (wsum > 0).astype(jnp.float32)
        total = (task + alpha * dist + aux) / n
        metrics = {"loss": total, "task_loss": task, "distill_loss": dist,
                   "aux_loss": aux, "alpha": alpha, "accuracy": acc,
                   "staleness": stale, "peer_weight": wsum}
        return total, metrics, None

    def post_update(self, state, params, opt, batch_all, aux, k):
        return TrainState(params, opt, state.step + 1)


class ShardMapCompressed(PredictionExchange):
    """Prediction exchange with an explicitly scheduled compressed wire.

    The pure-pjit prediction step lets XLA place the cross-pod exchange —
    fine for raw logits, but compiler-chosen placement defeats producer-side
    COMPRESSION (XLA may move the raw logits and compress afterwards). This
    strategy pins the schedule by construction: manual ``shard_map`` over
    ``"pod"`` (``"data"``/``"model"`` stay automatic, so FSDP/TP inside the
    pod is unchanged), each pod computes its model's forward + task loss +
    the compressed wire locally, and ``jax.lax.all_gather(wire, "pod")`` is
    the ONLY cross-pod communication. ``stop_gradient`` on the received wire
    keeps the backward pass pod-local. Off steps reuse the prediction
    strategy's collective-free variant.
    """

    name = "shardmap"
    variants = ("on", "off")

    def __init__(self, codist: CodistConfig, mesh):
        super().__init__(codist)
        self.mesh = mesh
        if "pod" not in mesh.axis_names:
            raise ValueError("ShardMapCompressed needs a mesh with a 'pod' "
                             f"axis; got {mesh.axis_names}")

    def loss(self, model, tc, sch, state, params, batch, variant):
        if variant == "off":
            return super().loss(model, tc, sch, state, params, batch, "off")
        from jax.sharding import PartitionSpec as P
        codist, mesh, n = self.codist, self.mesh, self.codist.n_models

        def lead_spec(tree):
            return jax.tree.map(
                lambda x: P(*(["pod"] + [None] * (x.ndim - 1))), tree)

        def per_pod(params_1, batch_1):
            p = jax.tree.map(lambda x: x[0], params_1)
            b = jax.tree.map(lambda x: x[0], batch_1)
            logits, aux = _task_forward(model, p, b, tc.remat)
            task = cd.cross_entropy(logits, b["labels"], sch.ls(state.step),
                                    b.get("mask"), fused=tc.fused_losses)
            # local compression, explicit cross-pod gather of the wire
            wire = cd.compress_targets(codist, jax.lax.stop_gradient(logits))
            wires_all = jax.tree.map(
                lambda x: jax.lax.all_gather(x, "pod"), wire)
            idx = jax.lax.axis_index("pod")
            dist = jnp.zeros((), jnp.float32)
            for j in range(n):
                wire_j = jax.tree.map(lambda x: x[j], wires_all)
                d = cd.distill_vs_compressed(codist, logits, wire_j,
                                             b.get("mask"),
                                             fused=tc.fused_losses)
                dist = dist + jnp.where(idx == j, 0.0, d)
            dist = dist / (n - 1)
            total = task + sch.alpha(state.step) * dist + aux
            out = jnp.stack([total, task, dist, aux])
            return out[None]  # (1, 4): pod-sharded metrics row

        per_pod_mapped = compat.shard_map(
            per_pod, mesh=mesh,
            in_specs=(lead_spec(params), lead_spec(batch)),
            out_specs=P("pod", None),
            check_vma=False, axis_names={"pod"})
        rows = per_pod_mapped(params, batch)         # (n, 4)
        total = jnp.mean(rows[:, 0])
        metrics = {"loss": total,
                   "task_loss": jnp.mean(rows[:, 1]),
                   "distill_loss": jnp.mean(rows[:, 2]),
                   "aux_loss": jnp.mean(rows[:, 3]),
                   "task_loss_per_model": rows[:, 1],
                   "distill_loss_per_model": rows[:, 2],
                   "alpha": sch.alpha(state.step)}
        return total, metrics, None


def resolve_strategy(codist: Optional[CodistConfig],
                     mesh=None) -> ExchangeStrategy:
    """CodistConfig -> strategy. ``mesh`` (with a "pod" axis) selects the
    explicit-collective compressed exchange; otherwise the config's
    ``pipelined`` / ``mode`` fields pick the mechanism, mirroring the old
    host-loop dispatch."""
    if codist is None:
        return AllReduce()
    if mesh is not None:
        return ShardMapCompressed(codist, mesh)
    if codist.pipelined:
        return PipelinedPredictions(codist)
    if codist.mode == "checkpoints":
        return CheckpointExchange(codist)
    return PredictionExchange(codist)


STRATEGIES = {cls.name: cls for cls in
              (AllReduce, PredictionExchange, CheckpointExchange,
               PipelinedPredictions, ShardMapCompressed, AsyncPrediction)}


# ----------------------------------------------------------------------------
# the unified builder
# ----------------------------------------------------------------------------

class StepBundle:
    """Compiled variants of one strategy plus the plan-driven dispatcher."""

    def __init__(self, strategy: ExchangeStrategy,
                 variants: Dict[str, Callable], eval_fn: Callable):
        self.strategy = strategy
        self.variants = variants     # raw (unjitted) step fns
        self.eval_fn = eval_fn       # raw eval fn
        self._jitted: Dict[str, Callable] = {}

    def jitted(self, variant: str = "on") -> Callable:
        if variant not in self._jitted:
            self._jitted[variant] = jax.jit(self.variants[variant])
        return self._jitted[variant]

    def apply(self, state, batch_all: Dict, step_idx: int):
        """One host-loop iteration: plan -> (optional) host exchange ->
        compiled variant. Returns ``(state, metrics, plan)``."""
        plan = self.strategy.plan(step_idx)
        if plan.exchange:
            state = self.strategy.host_exchange(state)
        state, metrics = self.jitted(self.strategy.variant_for(plan))(
            state, batch_all)
        return state, metrics, plan


def build_train_step(model, tc: TrainConfig, codist: Optional[CodistConfig],
                     strategy: ExchangeStrategy,
                     trainable: Optional[PyTree] = None) -> StepBundle:
    """The single entry point: every strategy's step variants share ONE
    schedules/optimizer/microbatch/trainable path."""
    codist = codist if codist is not None else strategy.codist
    sch = Schedules(*make_schedules(tc, codist))
    _, opt_update = make_optimizer(tc.optimizer, momentum=tc.momentum,
                                   b1=tc.adam_b1, b2=tc.adam_b2,
                                   dtype=tc.opt_dtype)

    def make_variant(variant: str) -> Callable:
        def step(state, batch_all: Dict):
            operand = strategy.prepare(state, batch_all, tc.microbatch)

            def loss_fn(params, b):
                total, metrics, aux = strategy.loss(model, tc, sch, state,
                                                    params, b, variant)
                return total, (metrics, aux)

            grads, metrics, aux = _grads_metrics_aux(
                loss_fn, state.params, operand, tc.microbatch,
                jnp.dtype(tc.accum_dtype))
            params, opt = opt_update(state.params, grads, state.opt,
                                     sch.lr(state.step), sch.wd(state.step),
                                     trainable)
            metrics.update(lr=sch.lr(state.step), wd=sch.wd(state.step))
            new_state = strategy.post_update(state, params, opt, batch_all,
                                             aux, tc.microbatch)
            return new_state, metrics
        return step

    variants = {v: make_variant(v) for v in strategy.variants}
    return StepBundle(strategy, variants, strategy.make_eval(model, tc))


# ----------------------------------------------------------------------------
# host-side exchange ops & eval steps
# ----------------------------------------------------------------------------

@jax.jit
def refresh_stale(state: CodistState) -> CodistState:
    """The checkpoint exchange: stale <- current params (cross-pod all-gather
    in the sharded setting: params are pod-sharded, stale is pod-replicated)."""
    return state._replace(stale=jax.tree.map(jnp.array, state.params))


def make_eval_step(model, tc: Optional[TrainConfig] = None) -> Callable:
    fused = tc.fused_losses if tc is not None else None

    def eval_step(params: PyTree, batch: Dict) -> Dict:
        logits, _ = _task_forward(model, params, batch, False)
        return {
            "eval_loss": cd.cross_entropy(logits, batch["labels"],
                                          0.0, batch.get("mask"),
                                          fused=fused),
            "eval_accuracy": cd.accuracy(logits, batch["labels"],
                                         batch.get("mask")),
        }
    return eval_step


def make_codist_eval_step(model, tc: Optional[TrainConfig] = None) -> Callable:
    fused = tc.fused_losses if tc is not None else None

    def eval_step(stacked_params: PyTree, batch_all: Dict) -> Dict:
        logits_all, _ = _stacked_forward(model, stacked_params, batch_all,
                                         False)
        loss = jax.vmap(lambda lg, lb: cd.cross_entropy(lg, lb, fused=fused))(
            logits_all, batch_all["labels"])
        acc = jax.vmap(cd.accuracy)(logits_all, batch_all["labels"])
        return {"eval_loss": jnp.mean(loss), "eval_loss_per_model": loss,
                "eval_accuracy": jnp.mean(acc), "eval_accuracy_per_model": acc}
    return eval_step
