"""Compatibility shims for the jax API surface this repo targets.

The codebase is written against the current jax API (``jax.shard_map``,
``jax.set_mesh``); these helpers fall back to the older spellings so the same
code runs on the pinned container runtime. Mesh-related shims live in
``repro.launch.mesh`` (``set_mesh``, ``abstract_mesh``).
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``.

    ``axis_names`` is the set of MANUAL axes (new-API semantics); on old jax
    it maps to ``auto = mesh.axis_names - axis_names`` and ``check_vma`` to
    ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - set(axis_names or mesh.axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
