"""Deterministic metrics: counters, gauges, and fixed-bucket histograms
with exact quantiles.

This is the ONE implementation of percentile/quantile math in the repo —
the fleet's TTFT/e2e p50/p99, the chaos router's slowest-quantile hedging
threshold, and the runtime's staleness statistics all go through
:class:`Histogram`, replacing the ad-hoc ``np.percentile``/``np.quantile``
call sites that had drifted across modules. Quantiles are **exact** (linear
interpolation over the full retained sample, numerically identical to
``np.percentile``'s default method — the retained-sample sizes here are
simulation-scale, thousands not billions); the fixed buckets exist for the
exported distribution shape, not as an approximation of the quantiles.

Everything is a pure function of the observation stream, so a registry
export for a seeded run is bit-identical across reruns — metrics files are
CI-gateable artifacts exactly like traces and SLO reports.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

METRICS_SCHEMA_VERSION = 1

Number = Union[int, float]

# default fixed bucket upper bounds for latency-like values (ms): roughly
# log-spaced, wide enough for both decode-tick costs and e2e latencies
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
    1000.0, 2000.0, 5000.0, 10000.0)


class Counter:
    """Monotonically accumulating value (int-exact when fed ints)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Number = 0

    def inc(self, amount: Number = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter increment {amount} is negative")
        self.value += amount

    def to_dict(self) -> Number:
        return self.value


class Gauge:
    """Last-set value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Number = 0

    def set(self, value: Number) -> None:
        self.value = value

    def to_dict(self) -> Number:
        return self.value


class Histogram:
    """Fixed-bucket histogram that also retains the exact sample.

    ``percentile(q)`` (q in [0, 100]) and ``quantile(q)`` (q in [0, 1])
    reproduce ``np.percentile`` / ``np.quantile`` bit-for-bit on the
    observation stream — the call sites this class replaced used those
    directly, and the bit-identical CI gates (SLO reports, bench rows)
    must not move.
    """

    __slots__ = ("buckets", "bucket_counts", "values", "_sum")

    def __init__(self, buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        if list(buckets) != sorted(buckets):
            raise ValueError(f"bucket bounds must be sorted: {buckets}")
        self.buckets = tuple(float(b) for b in buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +overflow
        self.values: List[float] = []
        self._sum = 0.0

    def observe(self, value: Number) -> None:
        v = float(value)
        self.values.append(v)
        self._sum += v
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float:
        """Exact percentile (q in [0, 100]); 0.0 on an empty histogram —
        the convention of the fleet report it replaced."""
        if not self.values:
            return 0.0
        return float(np.percentile(np.asarray(self.values), q))

    def quantile(self, q: float) -> float:
        """Exact quantile (q in [0, 1]) over the float64 sample — the
        hedging-threshold convention it replaced."""
        if not self.values:
            return 0.0
        return float(np.quantile(np.asarray(self.values, np.float64), q))

    def to_dict(self) -> Dict:
        d: Dict = {
            "count": self.count,
            "sum": self._sum,
            "min": min(self.values) if self.values else 0.0,
            "max": max(self.values) if self.values else 0.0,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "buckets": {},
        }
        for i, b in enumerate(self.buckets):
            d["buckets"][f"le_{b:g}"] = self.bucket_counts[i]
        d["buckets"]["le_inf"] = self.bucket_counts[-1]
        return d


class MetricsRegistry:
    """Named counters/gauges/histograms with a deterministic export.

    Get-or-create accessors: ``registry.counter("fleet/decode_tokens")``
    returns the same object every call. Names are free-form; the repo's
    convention is ``<subsystem>/<metric>`` (docs/observability.md lists
    what each subsystem emits).
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter()
        return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge()
        return self._gauges[name]

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None) -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram(buckets or DEFAULT_BUCKETS)
        return self._histograms[name]

    def to_dict(self) -> Dict:
        return {
            "schema_version": METRICS_SCHEMA_VERSION,
            "counters": {k: c.to_dict()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.to_dict()
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._histograms.items())},
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=1)

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")
