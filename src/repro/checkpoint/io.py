"""Pytree checkpointing: npz payload + json treedef.

Flat key encoding uses jax.tree_util key-paths, so any nested dict/tuple/
NamedTuple state (TrainState, CodistState, OptState) round-trips. Used by the
examples/launchers and by checkpoint-exchange experiments that restart from a
published replica.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    np.savez(path + ".npz", **{f"leaf_{i}": np.asarray(x)
                               for i, x in enumerate(leaves)})
    with open(path + ".tree.json", "w") as f:
        json.dump({"treedef": str(treedef), "n_leaves": len(leaves)}, f)


def snapshot_path(directory: str, peer: int) -> str:
    """Keep-latest snapshot slot for one async-runtime peer."""
    return os.path.join(directory, f"peer{peer}")


def save_snapshot(directory: str, peer: int, state: PyTree) -> None:
    """Overwrite peer's latest snapshot (the async runtime's recovery point:
    a failed peer rejoins from here instead of a fresh init)."""
    save_pytree(snapshot_path(directory, peer), state)


def has_snapshot(directory: str, peer: int) -> bool:
    return os.path.exists(snapshot_path(directory, peer) + ".npz")


def load_snapshot(directory: str, peer: int, like: PyTree) -> PyTree:
    return load_pytree(snapshot_path(directory, peer), like)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path + ".npz")
    leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    like_leaves, treedef = _flatten(like)
    assert len(leaves) == len(like_leaves), "checkpoint/template mismatch"
    import jax.numpy as jnp
    restored = [jnp.asarray(x, dtype=l.dtype) for x, l in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored)
