"""Batched serving engine: prefill + decode with jitted step functions.

Serves a single model (codistillation is a *training* mechanism — one of its
selling points, Section 6.6, is that only one model is needed at inference).
Supports greedy and temperature sampling, batched requests of equal prompt
length (continuous batching is out of scope for the dry-run container; the
decode step itself is batch-first and cache-slot-addressable, which is the
substrate continuous batching needs).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass
class GenerationResult:
    tokens: jax.Array        # (B, prompt+generated)
    prompt_len: int
    logprobs: Optional[jax.Array] = None


class Engine:
    def __init__(self, model, params: PyTree, cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(self._prefill_impl, static_argnums=(2,))
        self._decode = jax.jit(self._decode_impl)

    # -- jitted internals ----------------------------------------------------
    def _prefill_impl(self, params, batch, cap):
        return self.model.prefill(params, batch, cap,
                                  cache_dtype=self.cache_dtype)

    def _decode_impl(self, params, cache, tokens, pos):
        return self.model.decode(params, cache, tokens, pos)

    # -- public API ------------------------------------------------------------
    def generate(self, batch: Dict, max_new_tokens: int,
                 temperature: float = 0.0, seed: int = 0) -> GenerationResult:
        """batch: model inputs incl. 'tokens' (B, prompt_len) prompts."""
        prompt = batch["tokens"]
        b, prompt_len = prompt.shape
        # VLM: the patch prefix occupies cache slots before the prompt
        prefix = getattr(self.model.cfg, "num_patches", 0) or 0
        if "patches" not in batch:
            prefix = 0
        cap = prefix + prompt_len + max_new_tokens
        logits, cache = self._prefill(self.params, batch, cap)
        key = jax.random.key(seed)
        out_tokens = [prompt]
        tok = self._select(logits[:, -1], temperature, key)
        out_tokens.append(tok)
        for i in range(1, max_new_tokens):
            pos = jnp.asarray(prefix + prompt_len + i - 1, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok, pos)
            key, sub = jax.random.split(key)
            tok = self._select(logits[:, -1], temperature, sub)
            out_tokens.append(tok)
        return GenerationResult(jnp.concatenate(out_tokens, axis=1), prompt_len)

    @staticmethod
    def _select(logits: jax.Array, temperature: float, key) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature)[:, None].astype(jnp.int32)
