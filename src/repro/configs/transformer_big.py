"""transformer-big [paper's own NMT workload] — Vaswani et al. "big" [arXiv:1706.03762],
setup of Ott et al. [arXiv:1806.00187] on WMT'16 En-De, as used in Section 4.2.

6 enc + 6 dec blocks, d_model=1024 16H d_ff=4096 vocab=32768 (joint BPE).
"""
from repro.configs.base import ModelConfig, reduced as _reduced

CONFIG = ModelConfig(
    name="transformer-big",
    family="audio",  # reuses the enc-dec substrate; frontend is token embedding
    num_layers=6,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=32768,
    act="gelu",
    encoder_layers=6,
    num_audio_frames=0,  # 0 => encoder consumes source TOKENS, not stub frames
    source="Transformer big on WMT'16 En-De [arXiv:1706.03762, arXiv:1806.00187]",
)


def reduced():
    return _reduced(CONFIG)
