"""Flash attention (online softmax) Pallas TPU kernel with GQA + causal +
sliding-window support.

The jnp reference materializes (B, H, S, T) scores — the prefill hot spot at
32k context. This kernel tiles (BQ x BK) score blocks through VMEM with the
canonical (m, l, acc) online-softmax state, so HBM traffic is O(S*hd) and the
working set is a few MXU-aligned tiles.

Grid: (B*H, S/BQ, T/BK), innermost = KV blocks (accumulators carry across).
GQA is handled in the BlockSpec index maps: query row b*H+h reads KV row
b*KV + h//group. Causal/window masking is in-tile via iota comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
    v = v_ref[0].astype(jnp.float32)                  # (BK, hd)
    s = q @ k.T                                       # (BQ, BK)

    if causal or window > 0:
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + i * block_q
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * block_k
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (cols <= rows)
        if window > 0:
            mask = mask & (rows - cols < window)
        s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """GQA flash attention. q (B,S,H,hd); k,v (B,T,KV,hd) -> (B,S,H,hd).

    S % block_q == 0, T % block_k == 0, H % KV == 0.
    """
    b, sq, h, hd = q.shape
    _, tk, kvh, _ = k.shape
    assert h % kvh == 0 and sq % block_q == 0 and tk % block_k == 0
    g = h // kvh
    scale = hd ** -0.5
    n_q, n_k = sq // block_q, tk // block_k

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, tk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, tk, hd)

    def kv_row(bh, i, j):
        return (bh // h) * kvh + (bh % h) // g

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, i, j: (kv_row(bh, i, j), j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, i, j: (kv_row(bh, i, j), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
