"""Deterministic span/event tracer over the repo's *simulated* clocks.

Every subsystem in this codebase already runs on a deterministic virtual
timeline — the async runtime's virtual cluster clock (simulated seconds),
the serving fleet's decode-tick cost model (simulated milliseconds), and
the training loop's step counter. The tracer records spans, instants and
counter samples against those clocks and exports **Chrome trace-event
JSON** (the ``traceEvents`` array format), which Perfetto and
``chrome://tracing`` load directly. Because timestamps come from the
simulated clocks and the export is canonically ordered and serialized,
the trace file for a seeded run is **bit-identical across machines and
reruns** — traces are CI-gateable artifacts, exactly like the SLO reports
(``tools/trace_check.py`` validates structure; the ``trace-smoke`` CI job
diffs two runs byte-for-byte).

Event kinds (the Chrome ``ph`` phases used — see docs/observability.md for
the span taxonomy):

  * ``X`` complete spans  — engine ticks, peer steps (both endpoints known)
  * ``B``/``E`` begin/end — host-side scoped spans; nesting is enforced
  * ``b``/``e``/``n``     — nestable *async* spans keyed by ``(cat, id)``:
                            the per-request span trees, which survive
                            migration across peers (the id is the request
                            id, not the placement)
  * ``i`` instants        — publish / die / revive / preempt markers
  * ``C`` counters        — KV-pool occupancy, analytic decode HBM bytes
                            and FLOPs, mailbox staleness, comm bytes
  * ``M`` metadata        — process/thread naming for the UI

Times passed to the API are floats in the tracer's clock domain and are
quantized to integer microseconds via ``unit_us`` at record time (Chrome
``ts`` is microseconds): quantizing at record time, not export time, keeps
ordering and arithmetic integer-exact and therefore reproducible.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.fsio import atomic_write_text

TRACE_SCHEMA_VERSION = 1

# the ph phases this tracer emits (and tools/trace_check.py validates)
PHASES = ("X", "B", "E", "b", "e", "n", "i", "C", "M")


class TraceError(ValueError):
    """A recorded event violates a trace invariant (unbalanced span,
    non-monotonic clock, negative duration)."""


class Tracer:
    """Deterministic trace-event recorder on one simulated clock.

    ``unit_us`` converts the caller's clock domain into Chrome's
    microsecond ``ts``: 1000 for simulated milliseconds (the fleet),
    1_000_000 for simulated seconds (the async runtime), 1000 for training
    steps (one step renders as 1 ms). ``clock`` names the domain in the
    exported file so readers know what a microsecond means.
    """

    def __init__(self, unit_us: float = 1000.0, clock: str = "sim_ms"):
        if unit_us <= 0:
            raise TraceError(f"unit_us={unit_us} must be > 0")
        self.unit_us = float(unit_us)
        self.clock = clock
        self._events: List[Tuple[int, int, Dict[str, Any]]] = []  # (ts,seq,ev)
        self._seq = 0
        # (pid, tid) -> stack of (name, ts) for B/E balance + monotonicity
        self._open: Dict[Tuple[int, int], List[Tuple[str, int]]] = {}
        # (cat, id) -> stack of names for nestable-async balance
        self._open_async: Dict[Tuple[str, int], List[str]] = {}
        self._named: set = set()     # (kind, pid[, tid]) metadata emitted
        # optional FlightRecorder (obs/recorder.py): offered every event as
        # it is recorded so postmortem bundles can carry the last-N events
        # even while spans are still open (to_dict() refuses dangling spans)
        self.recorder: Optional[Any] = None

    # ---- helpers -----------------------------------------------------------
    def _ts(self, t: float) -> int:
        ts = int(round(float(t) * self.unit_us))
        if ts < 0:
            raise TraceError(f"negative timestamp {t} on a simulated clock")
        return ts

    def _push(self, ev: Dict[str, Any]) -> None:
        self._events.append((ev["ts"], self._seq, ev))
        if self.recorder is not None:
            self.recorder.offer(ev["ts"], self._seq, ev)
        self._seq += 1

    @staticmethod
    def _base(name: str, ph: str, ts: int, pid: int, tid: int,
              cat: str, args: Optional[Dict]) -> Dict[str, Any]:
        ev: Dict[str, Any] = {"name": name, "ph": ph, "ts": ts,
                              "pid": int(pid), "tid": int(tid), "cat": cat}
        if args:
            ev["args"] = args
        return ev

    # ---- naming metadata ---------------------------------------------------
    def name_process(self, pid: int, name: str) -> None:
        if ("p", pid) in self._named:
            return
        self._named.add(("p", pid))
        self._push({"name": "process_name", "ph": "M", "ts": 0,
                    "pid": int(pid), "tid": 0, "cat": "__metadata",
                    "args": {"name": name}})

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        if ("t", pid, tid) in self._named:
            return
        self._named.add(("t", pid, tid))
        self._push({"name": "thread_name", "ph": "M", "ts": 0,
                    "pid": int(pid), "tid": int(tid), "cat": "__metadata",
                    "args": {"name": name}})

    # ---- synchronous spans -------------------------------------------------
    def begin(self, name: str, t: float, *, pid: int = 0, tid: int = 0,
              cat: str = "span", args: Optional[Dict] = None) -> None:
        ts = self._ts(t)
        stack = self._open.setdefault((pid, tid), [])
        if stack and ts < stack[-1][1]:
            raise TraceError(
                f"begin({name!r}) at ts={ts} precedes its enclosing span "
                f"{stack[-1][0]!r} opened at ts={stack[-1][1]} "
                f"(track pid={pid} tid={tid}): simulated clocks are "
                "monotonic")
        stack.append((name, ts))
        self._push(self._base(name, "B", ts, pid, tid, cat, args))

    def end(self, name: str, t: float, *, pid: int = 0, tid: int = 0,
            cat: str = "span", args: Optional[Dict] = None) -> None:
        ts = self._ts(t)
        stack = self._open.get((pid, tid))
        if not stack:
            raise TraceError(f"end({name!r}) with no open span on track "
                             f"pid={pid} tid={tid}")
        top, ts0 = stack[-1]
        if top != name:
            raise TraceError(f"end({name!r}) does not match the innermost "
                             f"open span {top!r} (spans must nest)")
        if ts < ts0:
            raise TraceError(f"end({name!r}) at ts={ts} precedes its "
                             f"begin at ts={ts0}")
        stack.pop()
        self._push(self._base(name, "E", ts, pid, tid, cat, args))

    def complete(self, name: str, t0: float, t1: float, *, pid: int = 0,
                 tid: int = 0, cat: str = "span",
                 args: Optional[Dict] = None) -> None:
        ts0, ts1 = self._ts(t0), self._ts(t1)
        if ts1 < ts0:
            raise TraceError(f"complete({name!r}) duration is negative "
                             f"({ts0} -> {ts1})")
        ev = self._base(name, "X", ts0, pid, tid, cat, args)
        ev["dur"] = ts1 - ts0
        self._push(ev)

    def instant(self, name: str, t: float, *, pid: int = 0, tid: int = 0,
                cat: str = "span", args: Optional[Dict] = None) -> None:
        ev = self._base(name, "i", self._ts(t), pid, tid, cat, args)
        ev["s"] = "t"                # thread-scoped instant
        self._push(ev)

    # ---- nestable async spans (the per-request trees) ----------------------
    def async_begin(self, cat: str, aid: int, name: str, t: float, *,
                    pid: int = 0, tid: int = 0,
                    args: Optional[Dict] = None) -> None:
        self._open_async.setdefault((cat, aid), []).append(name)
        ev = self._base(name, "b", self._ts(t), pid, tid, cat, args)
        ev["id"] = int(aid)
        self._push(ev)

    def async_end(self, cat: str, aid: int, name: str, t: float, *,
                  pid: int = 0, tid: int = 0,
                  args: Optional[Dict] = None) -> None:
        stack = self._open_async.get((cat, aid))
        if not stack:
            raise TraceError(f"async_end({name!r}) with no open async span "
                             f"for (cat={cat!r}, id={aid})")
        if stack[-1] != name:
            raise TraceError(f"async_end({name!r}) does not match the "
                             f"innermost open async span {stack[-1]!r} for "
                             f"(cat={cat!r}, id={aid})")
        stack.pop()
        ev = self._base(name, "e", self._ts(t), pid, tid, cat, args)
        ev["id"] = int(aid)
        self._push(ev)

    def async_span(self, cat: str, aid: int, name: str, t0: float,
                   t1: float, *, pid: int = 0, tid: int = 0,
                   args: Optional[Dict] = None) -> None:
        """A closed child span of an async tree (both endpoints known)."""
        self.async_begin(cat, aid, name, t0, pid=pid, tid=tid, args=args)
        self.async_end(cat, aid, name, max(t0, t1), pid=pid, tid=tid)

    def async_instant(self, cat: str, aid: int, name: str, t: float, *,
                      pid: int = 0, tid: int = 0,
                      args: Optional[Dict] = None) -> None:
        ev = self._base(name, "n", self._ts(t), pid, tid, cat, args)
        ev["id"] = int(aid)
        self._push(ev)

    # ---- counter streams ---------------------------------------------------
    def counter(self, name: str, t: float, values: Dict[str, float], *,
                pid: int = 0, tid: int = 0, cat: str = "counter") -> None:
        self._push(self._base(name, "C", self._ts(t), pid, tid, cat,
                              dict(values)))

    # ---- export ------------------------------------------------------------
    @property
    def n_events(self) -> int:
        return len(self._events)

    def open_spans(self) -> List[str]:
        """Names of spans begun but not yet ended (sync and async)."""
        out = [name for stack in self._open.values() for name, _ in stack]
        out.extend(name for stack in self._open_async.values()
                   for name in stack)
        return out

    def to_dict(self) -> Dict[str, Any]:
        dangling = self.open_spans()
        if dangling:
            raise TraceError("export with unbalanced spans still open: "
                             + ", ".join(sorted(dangling)))
        # canonical order: by quantized ts, then recording sequence — so a
        # begin always precedes the matching end at equal timestamps and the
        # exported array is sorted (tools/trace_check.py enforces this)
        events = [ev for _, _, ev in sorted(self._events,
                                            key=lambda e: (e[0], e[1]))]
        return {
            "displayTimeUnit": "ms",
            "otherData": {"clock": self.clock,
                          "schema_version": TRACE_SCHEMA_VERSION,
                          "unit_us": self.unit_us},
            "traceEvents": events,
        }

    def to_json(self) -> str:
        # sort_keys + fixed separators: byte-identical serialization for
        # identical event streams (the trace-smoke CI gate)
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def save(self, path: str) -> None:
        atomic_write_text(path, self.to_json() + "\n")


def for_sim_ms() -> Tracer:
    """Tracer on the serving fleet's simulated-millisecond clock."""
    return Tracer(unit_us=1000.0, clock="sim_ms")


def for_sim_seconds() -> Tracer:
    """Tracer on the async runtime's simulated-seconds clock."""
    return Tracer(unit_us=1_000_000.0, clock="sim_s")


def for_steps() -> Tracer:
    """Tracer on a step-counter clock (synchronous training): one step
    renders as one millisecond."""
    return Tracer(unit_us=1000.0, clock="steps")
