"""Fused codistillation-loss Pallas TPU kernels (the paper's D(y, y')).

Computes the per-token distillation loss between two logit tensors without
materializing any (T, V) fp32 temporary: vocab tiles stream through VMEM and
per-row accumulators carry across the innermost grid dimension.

Modes:
  * ``mse`` — mean over vocab of (a - b)^2, the paper's loss (A.3:
    "mean squared error between the logits of the two models");
  * ``kl``  — KL(softmax(target) || softmax(logits)) via a streaming
    five-accumulator form (online logsumexp for BOTH operands plus the
    max-rescaled cross term), Anil/Zhang et al.'s loss.

Both read each logit tile exactly once. The residual variants additionally
emit the per-token normalizers so the matching BACKWARD kernels
(``fused_distill_mse_grad`` / ``fused_distill_kl_grad``) can rebuild both
softmaxes in a single second pass:

  mse:  dA =  g * 2 (a - b) / V,            dB = -dA        (no residuals)
  kl:   dLs = g * (softmax(ls) - softmax(lt))
        dLt = g * softmax(lt) * ((lt - ls) - E[lt - ls])
        from residuals (logZ_t, logZ_s, E = U/S_t).

These are the kernels that make every-step prediction exchange affordable at
LM vocabulary sizes; ``ops.py`` wraps them in ``jax.custom_vjp`` entry points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_ce import pl_scratch
from repro.kernels.fused_ce import tile_spec as _tile_spec
from repro.kernels.fused_ce import tok_spec as _tok_spec

NEG = -1e30


def _mse_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_v: int, v_total: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = a - b
    acc_ref[...] = acc_ref[...] + jnp.sum(d * d, axis=-1)

    @pl.when(j == n_v - 1)
    def _fin():
        out_ref[...] = acc_ref[...] / v_total


def _kl_accumulate(s_logits_ref, t_logits_ref, mt_ref, st_ref, ms_ref, ss_ref,
                   u_ref):
    """One vocab tile of the streaming five-accumulator KL form."""
    lt = t_logits_ref[...].astype(jnp.float32)
    ls = s_logits_ref[...].astype(jnp.float32)

    # target-side online logsumexp + rescaled cross term U = sum e^{lt-Mt}(lt-ls)
    mt_prev = mt_ref[...]
    mt_new = jnp.maximum(mt_prev, jnp.max(lt, axis=-1))
    alpha_t = jnp.exp(mt_prev - mt_new)
    w = jnp.exp(lt - mt_new[:, None])
    st_ref[...] = st_ref[...] * alpha_t + jnp.sum(w, axis=-1)
    u_ref[...] = u_ref[...] * alpha_t + jnp.sum(w * (lt - ls), axis=-1)
    mt_ref[...] = mt_new

    # student-side online logsumexp
    ms_prev = ms_ref[...]
    ms_new = jnp.maximum(ms_prev, jnp.max(ls, axis=-1))
    ss_ref[...] = ss_ref[...] * jnp.exp(ms_prev - ms_new) + jnp.sum(
        jnp.exp(ls - ms_new[:, None]), axis=-1)
    ms_ref[...] = ms_new


def _kl_init(mt_ref, st_ref, ms_ref, ss_ref, u_ref):
    mt_ref[...] = jnp.full_like(mt_ref, NEG)
    ms_ref[...] = jnp.full_like(ms_ref, NEG)
    st_ref[...] = jnp.zeros_like(st_ref)
    ss_ref[...] = jnp.zeros_like(ss_ref)
    u_ref[...] = jnp.zeros_like(u_ref)


def _kl_kernel(s_logits_ref, t_logits_ref, out_ref,
               mt_ref, st_ref, ms_ref, ss_ref, u_ref, *, n_v: int):
    """KL(softmax(t) || softmax(s)) streamed over vocab tiles."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _kl_init(mt_ref, st_ref, ms_ref, ss_ref, u_ref)

    _kl_accumulate(s_logits_ref, t_logits_ref, mt_ref, st_ref, ms_ref, ss_ref,
                   u_ref)

    @pl.when(j == n_v - 1)
    def _fin():
        log_zt = mt_ref[...] + jnp.log(st_ref[...])
        log_zs = ms_ref[...] + jnp.log(ss_ref[...])
        out_ref[...] = u_ref[...] / st_ref[...] - log_zt + log_zs


def _kl_parts_kernel(s_logits_ref, t_logits_ref, out_ref, logzs_ref,
                     logzt_ref, e_ref, mt_ref, st_ref, ms_ref, ss_ref, u_ref,
                     *, n_v: int):
    """KL forward that also emits the (logZ_s, logZ_t, E) residuals."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _kl_init(mt_ref, st_ref, ms_ref, ss_ref, u_ref)

    _kl_accumulate(s_logits_ref, t_logits_ref, mt_ref, st_ref, ms_ref, ss_ref,
                   u_ref)

    @pl.when(j == n_v - 1)
    def _fin():
        log_zt = mt_ref[...] + jnp.log(st_ref[...])
        log_zs = ms_ref[...] + jnp.log(ss_ref[...])
        e = u_ref[...] / st_ref[...]
        out_ref[...] = e - log_zt + log_zs
        logzs_ref[...] = log_zs
        logzt_ref[...] = log_zt
        e_ref[...] = e


@functools.partial(jax.jit,
                   static_argnames=("mode", "block_t", "block_v", "v_total",
                                    "interpret"))
def fused_distill_loss(logits: jax.Array, target_logits: jax.Array,
                       mode: str = "mse", block_t: int = 256,
                       block_v: int = 512, v_total: int = 0,
                       interpret: bool = False) -> jax.Array:
    """Per-token distillation loss. (T, V) x2 -> (T,) fp32.

    ``v_total`` overrides the MSE mean denominator (default: padded V) so
    callers that pad the vocab with equal values in both operands get the
    unpadded mean directly.
    """
    t, v = logits.shape
    assert logits.shape == target_logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    n_t, n_v = t // block_t, v // block_v
    if mode == "mse":
        kernel = functools.partial(_mse_kernel, n_v=n_v, v_total=v_total or v)
        scratch = [pl_scratch((block_t,))]
    elif mode == "kl":
        kernel = functools.partial(_kl_kernel, n_v=n_v)
        scratch = [pl_scratch((block_t,)) for _ in range(5)]
    else:
        raise ValueError(mode)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[_tile_spec(block_t, block_v), _tile_spec(block_t, block_v)],
        out_specs=_tok_spec(block_t),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(logits, target_logits)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v",
                                             "interpret"))
def fused_distill_kl_parts(logits: jax.Array, target_logits: jax.Array,
                           block_t: int = 256, block_v: int = 512,
                           interpret: bool = False):
    """KL forward returning (loss, logZ_s, logZ_t, E) — all (T,) fp32."""
    t, v = logits.shape
    assert logits.shape == target_logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    n_t, n_v = t // block_t, v // block_v
    kernel = functools.partial(_kl_parts_kernel, n_v=n_v)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[_tile_spec(block_t, block_v), _tile_spec(block_t, block_v)],
        out_specs=[_tok_spec(block_t) for _ in range(4)],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.float32)] * 4,
        scratch_shapes=[pl_scratch((block_t,)) for _ in range(5)],
        interpret=interpret,
    )(logits, target_logits)


# ----------------------------------------------------------------------------
# backward kernels (single pass, no cross-tile carry)
# ----------------------------------------------------------------------------

def _mse_grad_kernel(a_ref, b_ref, g_ref, da_ref, db_ref, *, v_total: int):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    da = g_ref[...][:, None] * (2.0 / v_total) * (a - b)
    da_ref[...] = da.astype(da_ref.dtype)
    db_ref[...] = (-da).astype(db_ref.dtype)


def _kl_grad_kernel(s_ref, t_ref, logzs_ref, logzt_ref, e_ref, g_ref,
                    ds_ref, dt_ref):
    ls = s_ref[...].astype(jnp.float32)
    lt = t_ref[...].astype(jnp.float32)
    q = jnp.exp(ls - logzs_ref[...][:, None])        # softmax(student)
    p = jnp.exp(lt - logzt_ref[...][:, None])        # softmax(target)
    g = g_ref[...][:, None]
    ds_ref[...] = (g * (q - p)).astype(ds_ref.dtype)
    dt_ref[...] = (g * p * ((lt - ls) - e_ref[...][:, None])).astype(
        dt_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "v_total",
                                             "interpret"))
def fused_distill_mse_grad(logits: jax.Array, target_logits: jax.Array,
                           g: jax.Array, block_t: int = 256,
                           block_v: int = 512, v_total: int = 0,
                           interpret: bool = False):
    """(dlogits, dtarget) for per-token grads ``g``. dB = -dA = -g*2(a-b)/V."""
    t, v = logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    kernel = functools.partial(_mse_grad_kernel, v_total=v_total or v)
    return pl.pallas_call(
        kernel,
        grid=(t // block_t, v // block_v),
        in_specs=[_tile_spec(block_t, block_v), _tile_spec(block_t, block_v),
                  _tok_spec(block_t)],
        out_specs=[_tile_spec(block_t, block_v),
                   _tile_spec(block_t, block_v)],
        out_shape=[jax.ShapeDtypeStruct((t, v), logits.dtype),
                   jax.ShapeDtypeStruct((t, v), target_logits.dtype)],
        interpret=interpret,
    )(logits, target_logits, g)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v",
                                             "interpret"))
def fused_distill_kl_grad(logits: jax.Array, target_logits: jax.Array,
                          logzs: jax.Array, logzt: jax.Array, e: jax.Array,
                          g: jax.Array, block_t: int = 256,
                          block_v: int = 512, interpret: bool = False):
    """(dlogits, dtarget) from the saved five-accumulator residuals.

    Both softmaxes are rebuilt tile-by-tile from (logZ_s, logZ_t); the
    target-side gradient uses E = E_{softmax(t)}[lt - ls] saved forward.
    """
    t, v = logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    return pl.pallas_call(
        _kl_grad_kernel,
        grid=(t // block_t, v // block_v),
        in_specs=[_tile_spec(block_t, block_v), _tile_spec(block_t, block_v),
                  _tok_spec(block_t), _tok_spec(block_t), _tok_spec(block_t),
                  _tok_spec(block_t)],
        out_specs=[_tile_spec(block_t, block_v),
                   _tile_spec(block_t, block_v)],
        out_shape=[jax.ShapeDtypeStruct((t, v), logits.dtype),
                   jax.ShapeDtypeStruct((t, v), target_logits.dtype)],
        interpret=interpret,
    )(logits, target_logits, logzs, logzt, e, g)
