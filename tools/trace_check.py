#!/usr/bin/env python3
"""Validate Chrome/Perfetto trace-event JSON produced by ``repro.obs``.

    python tools/trace_check.py out.json [more.json ...]

Checks (exit 0 = every file valid, 1 = a violation, 2 = unreadable/usage):

  * top-level schema: a ``traceEvents`` array plus the ``otherData`` clock
    stamp written by :class:`repro.obs.trace.Tracer`;
  * every event has a known ``ph`` phase and ``name``/``pid``/``tid``,
    integer ``ts >= 0`` (metadata events are pinned at ts 0);
  * the array is sorted by ``ts`` (the tracer's canonical order — a
    simulated clock never runs backwards);
  * complete events (``X``) carry integer ``dur >= 0``;
  * ``B``/``E`` spans balance per ``(pid, tid)`` track with LIFO name
    matching (spans nest);
  * nestable async spans (``b``/``e``) balance per ``(cat, id)`` — the
    per-request trees close even when a request migrates across peers;
  * async events (``b``/``e``/``n``) carry an ``id``.

Used by the ``trace-smoke`` CI job next to the byte-identity diff: the
diff proves determinism, this proves the file is a well-formed trace.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

PHASES = {"X", "B", "E", "b", "e", "n", "i", "C", "M"}


def check_events(events: List[Dict], errors: List[str]) -> None:
    last_ts = None
    open_sync: Dict[tuple, List[tuple]] = {}
    open_async: Dict[tuple, List[str]] = {}
    for i, ev in enumerate(events):
        where = f"event[{i}] {ev.get('name', '?')!r}"
        ph = ev.get("ph")
        if ph not in PHASES:
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        ts = ev.get("ts")
        if not isinstance(ts, int) or ts < 0:
            errors.append(f"{where}: ts must be a non-negative integer, "
                          f"got {ts!r}")
            continue
        if ph == "M":
            if ts != 0:
                errors.append(f"{where}: metadata events are pinned at ts 0")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(f"{where}: ts {ts} < previous event ts {last_ts} "
                          "(traceEvents must be sorted: simulated clocks "
                          "are monotonic)")
        last_ts = ts
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, int) or dur < 0:
                errors.append(f"{where}: X event needs integer dur >= 0, "
                              f"got {dur!r}")
        elif ph == "B":
            open_sync.setdefault(track, []).append((ev.get("name"), ts))
        elif ph == "E":
            stack = open_sync.get(track)
            if not stack:
                errors.append(f"{where}: E with no open B on track {track}")
            else:
                name, ts0 = stack.pop()
                if name != ev.get("name"):
                    errors.append(f"{where}: E closes {ev.get('name')!r} "
                                  f"but innermost open span is {name!r}")
                if ts < ts0:
                    errors.append(f"{where}: E at ts {ts} precedes its B "
                                  f"at ts {ts0}")
        elif ph in ("b", "e", "n"):
            if "id" not in ev:
                errors.append(f"{where}: async event missing id")
                continue
            key = (ev.get("cat"), ev.get("id"))
            if ph == "b":
                open_async.setdefault(key, []).append(ev.get("name"))
            elif ph == "e":
                stack = open_async.get(key)
                if not stack:
                    errors.append(f"{where}: async e with no open b for "
                                  f"(cat, id)={key}")
                elif stack[-1] != ev.get("name"):
                    errors.append(f"{where}: async e closes "
                                  f"{ev.get('name')!r} but innermost open "
                                  f"async span is {stack[-1]!r}")
                else:
                    stack.pop()
    for track, stack in sorted(open_sync.items(), key=str):
        for name, ts0 in stack:
            errors.append(f"span {name!r} on track {track} opened at ts "
                          f"{ts0} never closed")
    for key, stack in sorted(open_async.items(), key=str):
        for name in stack:
            errors.append(f"async span {name!r} for (cat, id)={key} "
                          "never closed")


def check_file(path: str) -> List[str]:
    errors: List[str] = []
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return [f"{path}: not a trace-event JSON object with 'traceEvents'"]
    other = doc.get("otherData")
    if not isinstance(other, dict) or "clock" not in other \
            or "schema_version" not in other:
        errors.append(f"{path}: missing otherData clock/schema_version "
                      "stamp (not produced by repro.obs?)")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return [f"{path}: traceEvents is not an array"]
    check_events(events, errors)
    return [f"{path}: {e}" for e in errors]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/trace_check.py",
        description="Validate repro.obs Chrome/Perfetto trace JSON.")
    ap.add_argument("traces", nargs="+", help="trace JSON files to check")
    args = ap.parse_args(argv)
    failed = False
    for path in args.traces:
        try:
            errors = check_file(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({type(e).__name__}: {e})",
                  file=sys.stderr)
            return 2
        if errors:
            failed = True
            for e in errors:
                print(e, file=sys.stderr)
        else:
            with open(path) as f:
                n = len(json.load(f)["traceEvents"])
            print(f"{path}: OK ({n} events)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
