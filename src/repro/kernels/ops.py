"""Jit'd public wrappers around the Pallas kernels, including the
``jax.custom_vjp`` fused-loss entry points used by training.

On CPU (this container) the kernels execute in ``interpret=True`` mode for
validation; on TPU they compile to Mosaic. ``auto_interpret()`` picks per
backend so model code can call these unconditionally. Shapes are padded to
block multiples here so callers never worry about alignment.

Differentiable entry points (drop-ins for the jnp losses in
``core.codistillation``, dispatched there by the ``fused_losses`` flag):

  * ``fused_cross_entropy_loss``  — masked/smoothed mean CE; forward streams
    vocab tiles once, backward rebuilds softmax from the saved per-token
    ``logZ`` residual (CE gradient = softmax - smoothed-onehot);
  * ``fused_distill_mean``        — masked mean D(y, y') for mse / kl;
    MSE gradient = 2(a-b)/V, KL gradient from the five-accumulator residuals;
  * ``fused_ce_distill``          — COMBINED task CE + distill: the hot-path
    kernel that reads each (T, V) logits tile exactly once per model and
    emits both losses (and both gradients on the way back).

The custom-VJP boundary sits at the per-token level: masking, label-smoothing
mixing and the mean-reduction stay in plain (T,)-sized differentiable jnp, so
no (T, V) fp32 temporary exists outside the kernels in either direction.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.combined_loss import (
    fused_ce_distill_grad,
    fused_ce_distill_parts,
)
from repro.kernels.distill_loss import (
    fused_distill_kl_grad,
    fused_distill_kl_parts,
    fused_distill_loss,
    fused_distill_mse_grad,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_ce import (
    NEG,
    fused_cross_entropy,
    fused_cross_entropy_grad,
    fused_cross_entropy_parts,
)


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_losses_default() -> bool:
    """Default for the ``fused_losses`` runtime flag: on for TPU (Mosaic),
    off elsewhere — CPU callers opt in explicitly and run interpret-mode."""
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def cross_entropy_tokens(logits: jax.Array, labels: jax.Array,
                         block_t: int = 256, block_v: int = 512,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Per-token CE over the trailing vocab dim; any leading shape."""
    interpret = auto_interpret() if interpret is None else interpret
    lead = logits.shape[:-1]
    v = logits.shape[-1]
    t = int(jnp.prod(jnp.array(lead))) if lead else 1
    lg = logits.reshape(t, v)
    lb = labels.reshape(t)
    tp = (-t) % block_t
    lg = _pad_to(lg, 0, block_t)
    lg = _pad_to(lg, 1, block_v, value=NEG)
    lb = jnp.pad(lb, (0, tp))
    # padded vocab cols get -1e30 (never win max / never the label)
    out = fused_cross_entropy(lg, lb, block_t=block_t,
                              block_v=min(block_v, lg.shape[1]),
                              interpret=interpret)
    return out[:t].reshape(lead)


def distill_loss_tokens(logits: jax.Array, target_logits: jax.Array,
                        mode: str = "mse", block_t: int = 256,
                        block_v: int = 512,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Per-token distillation loss over the trailing vocab dim."""
    interpret = auto_interpret() if interpret is None else interpret
    lead = logits.shape[:-1]
    v = logits.shape[-1]
    t = int(jnp.prod(jnp.array(lead))) if lead else 1
    a = logits.reshape(t, v)
    b = target_logits.reshape(t, v)
    a = _pad_to(_pad_to(a, 0, block_t), 1, block_v,
                value=0.0 if mode == "mse" else NEG)
    b = _pad_to(_pad_to(b, 0, block_t), 1, block_v,
                value=0.0 if mode == "mse" else NEG)
    out = fused_distill_loss(a, b, mode=mode, block_t=block_t,
                             block_v=min(block_v, a.shape[1]),
                             interpret=interpret)
    if mode == "mse" and a.shape[1] != v:
        out = out * (a.shape[1] / v)  # undo the padded-vocab mean denominator
    return out[:t].reshape(lead)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              window: int = 0, block_q: int = 128, block_k: int = 128,
              interpret: Optional[bool] = None) -> jax.Array:
    """GQA flash attention with automatic seq padding."""
    interpret = auto_interpret() if interpret is None else interpret
    sq, tk = q.shape[1], k.shape[1]
    bq = min(block_q, max(16, sq))
    bk = min(block_k, max(16, tk))
    if not causal:
        # padded keys would receive softmax mass without a causal mask
        assert tk % bk == 0, "non-causal attention needs T % block_k == 0"
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    # causal mask makes padded keys unreachable from real queries (padded key
    # positions >= sq > any real query row); padded query rows are sliced off.
    out = flash_attention(qp, kp, vp, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :sq]


# ----------------------------------------------------------------------------
# custom-VJP fused losses
# ----------------------------------------------------------------------------
# The spec tuple (mode?, block_t, block_v, v_real, interpret) is the hashable
# nondiff argument; padded (T, V) arrays are the differentiable primals. Every
# per-token output is sliced/composed/reduced OUTSIDE the custom_vjp, in
# (T,)-sized jnp, so jax handles those cotangents and the kernels only ever
# see full-tile work.

def _int_zero(x: jax.Array):
    """Zero cotangent for an integer primal (labels)."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ce_parts_p(spec, logits, labels):
    bt, bv, v_real, interp = spec
    nll, smooth, _ = fused_cross_entropy_parts(
        logits, labels, block_t=bt, block_v=bv, v_real=v_real,
        interpret=interp)
    return nll, smooth


def _ce_parts_fwd(spec, logits, labels):
    bt, bv, v_real, interp = spec
    nll, smooth, logz = fused_cross_entropy_parts(
        logits, labels, block_t=bt, block_v=bv, v_real=v_real,
        interpret=interp)
    return (nll, smooth), (logits, labels, logz)


def _ce_parts_bwd(spec, res, g):
    bt, bv, v_real, interp = spec
    logits, labels, logz = res
    g_nll, g_smooth = g
    dx = fused_cross_entropy_grad(logits, labels, logz, g_nll, g_smooth,
                                  block_t=bt, block_v=bv, v_real=v_real,
                                  interpret=interp)
    return dx, _int_zero(labels)


_ce_parts_p.defvjp(_ce_parts_fwd, _ce_parts_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _distill_tokens_p(spec, logits, target):
    mode, bt, bv, v_real, interp = spec
    return fused_distill_loss(logits, target, mode=mode, block_t=bt,
                              block_v=bv, v_total=v_real, interpret=interp)


def _distill_tokens_fwd(spec, logits, target):
    mode, bt, bv, v_real, interp = spec
    if mode == "mse":
        loss = fused_distill_loss(logits, target, mode="mse", block_t=bt,
                                  block_v=bv, v_total=v_real,
                                  interpret=interp)
        return loss, (logits, target, ())
    loss, logzs, logzt, e = fused_distill_kl_parts(
        logits, target, block_t=bt, block_v=bv, interpret=interp)
    return loss, (logits, target, (logzs, logzt, e))


def _distill_tokens_bwd(spec, res, g):
    mode, bt, bv, v_real, interp = spec
    logits, target, extra = res
    if mode == "mse":
        da, db = fused_distill_mse_grad(logits, target, g, block_t=bt,
                                        block_v=bv, v_total=v_real,
                                        interpret=interp)
    else:
        logzs, logzt, e = extra
        da, db = fused_distill_kl_grad(logits, target, logzs, logzt, e, g,
                                       block_t=bt, block_v=bv,
                                       interpret=interp)
    return da, db


_distill_tokens_p.defvjp(_distill_tokens_fwd, _distill_tokens_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ce_distill_tokens_p(spec, logits, target, labels):
    mode, bt, bv, v_real, interp = spec
    (nll, smooth, dist), _ = fused_ce_distill_parts(
        logits, target, labels, mode=mode, block_t=bt, block_v=bv,
        v_real=v_real, interpret=interp)
    return nll, smooth, dist


def _ce_distill_tokens_fwd(spec, logits, target, labels):
    mode, bt, bv, v_real, interp = spec
    (nll, smooth, dist), residuals = fused_ce_distill_parts(
        logits, target, labels, mode=mode, block_t=bt, block_v=bv,
        v_real=v_real, interpret=interp)
    return (nll, smooth, dist), (logits, target, labels, residuals)


def _ce_distill_tokens_bwd(spec, res, g):
    mode, bt, bv, v_real, interp = spec
    logits, target, labels, residuals = res
    g_nll, g_smooth, g_dist = g
    # kl residuals: (logzs, logzt, e); mse: (logzs,) — grad kernels take the
    # tuple as leading (T,)-vector operands
    ds, dt = fused_ce_distill_grad(logits, target, labels, tuple(residuals),
                                   g_nll, g_smooth, g_dist, mode=mode,
                                   block_t=bt, block_v=bv, v_real=v_real,
                                   interpret=interp)
    return ds, dt, _int_zero(labels)


_ce_distill_tokens_p.defvjp(_ce_distill_tokens_fwd, _ce_distill_tokens_bwd)


# ----------------------------------------------------------------------------
# public fused-loss entry points (scalar, masked, drop-in for core losses)
# ----------------------------------------------------------------------------

def _masked_mean(per_tok: jax.Array, mask) -> jax.Array:
    """Exactly the jnp losses' masked mean: ``sum(loss * mask) / sum(mask)``
    with the ORIGINAL (unbroadcast) mask in the denominator — bit-for-bit the
    reference semantics for any mask broadcastable to the token shape."""
    if mask is not None:
        m_flat, m_raw = mask
        return (jnp.sum(per_tok * m_flat)
                / jnp.maximum(jnp.sum(m_raw.astype(jnp.float32)), 1.0))
    return jnp.mean(per_tok)


def _flat_mask(mask: Optional[jax.Array], lead: Tuple[int, ...], t: int):
    """(broadcast-flattened mask, original mask) or None."""
    if mask is None:
        return None
    return (jnp.broadcast_to(mask, lead).reshape(t).astype(jnp.float32),
            mask)


def _flatten_pad(logits: jax.Array, block_t: int, block_v: int,
                 pad_value: float) -> Tuple[jax.Array, int, int, int, int]:
    """(T, V)-flatten and block-pad; returns (padded, t, v, bt, bv)."""
    v = logits.shape[-1]
    t = 1
    for d in logits.shape[:-1]:
        t *= d
    bt = min(block_t, _round_up(max(t, 1), 8))
    bv = min(block_v, _round_up(v, 128))
    lg = _pad_to(_pad_to(logits.reshape(t, v), 0, bt), 1, bv, value=pad_value)
    return lg, t, v, bt, bv


def _flat_labels(labels: jax.Array, t: int, t_padded: int) -> jax.Array:
    lb = labels.reshape(t).astype(jnp.int32)
    return jnp.pad(lb, (0, t_padded - t))


def fused_cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                             label_smoothing: jax.Array | float = 0.0,
                             mask: Optional[jax.Array] = None,
                             block_t: int = 256, block_v: int = 512,
                             interpret: Optional[bool] = None) -> jax.Array:
    """Differentiable drop-in for ``codistillation.cross_entropy``.

    logits: (..., V) float; labels: (...) int; mask: (...) broadcastable.
    """
    interpret = auto_interpret() if interpret is None else interpret
    lg, t, v, bt, bv = _flatten_pad(logits, block_t, block_v, NEG)
    lb = _flat_labels(labels, t, lg.shape[0])
    spec = (bt, bv, v, bool(interpret))
    nll, smooth = _ce_parts_p(spec, lg, lb)
    ls = jnp.asarray(label_smoothing, jnp.float32)
    per_tok = (1.0 - ls) * nll[:t] + ls * smooth[:t]
    return _masked_mean(per_tok, _flat_mask(mask, logits.shape[:-1], t))


def fused_distill_mean(logits: jax.Array, target_logits: jax.Array,
                       mode: str = "mse", mask: Optional[jax.Array] = None,
                       block_t: int = 256, block_v: int = 512,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Differentiable drop-in for ``distill_mse`` / ``distill_kl``."""
    assert mode in ("mse", "kl"), mode
    interpret = auto_interpret() if interpret is None else interpret
    # mse pads with 0.0 (exact in every dtype => zero diff on padded cols);
    # kl needs the -1e30 sentinel so padded cols carry no softmax mass
    pad = 0.0 if mode == "mse" else NEG
    a, t, v, bt, bv = _flatten_pad(logits, block_t, block_v, pad)
    b, *_ = _flatten_pad(target_logits, block_t, block_v, pad)
    spec = (mode, bt, bv, v, bool(interpret))
    per_tok = _distill_tokens_p(spec, a, b)[:t]
    return _masked_mean(per_tok, _flat_mask(mask, logits.shape[:-1], t))


def fused_ce_distill(logits: jax.Array, target_logits: jax.Array,
                     labels: jax.Array,
                     mode: str = "mse",
                     label_smoothing: jax.Array | float = 0.0,
                     mask: Optional[jax.Array] = None,
                     block_t: int = 256, block_v: int = 512,
                     interpret: Optional[bool] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """(task CE, distill) scalars, reading each logits tile exactly once.

    The codistillation hot path: equivalent to
    ``(cross_entropy(logits, labels, ls, mask),
       distill_pair(mode, logits, target_logits, mask))``
    but one HBM sweep of the student logits instead of two.
    """
    assert mode in ("mse", "kl"), mode
    interpret = auto_interpret() if interpret is None else interpret
    lg, t, v, bt, bv = _flatten_pad(logits, block_t, block_v, NEG)
    tg, *_ = _flatten_pad(target_logits, block_t, block_v, NEG)
    lb = _flat_labels(labels, t, lg.shape[0])
    spec = (mode, bt, bv, v, bool(interpret))
    nll, smooth, dist = _ce_distill_tokens_p(spec, lg, tg, lb)
    ls = jnp.asarray(label_smoothing, jnp.float32)
    per_tok = (1.0 - ls) * nll[:t] + ls * smooth[:t]
    m = _flat_mask(mask, logits.shape[:-1], t)
    return _masked_mean(per_tok, m), _masked_mean(dist[:t], m)
