"""Mamba (selective SSM) block — used by the Jamba hybrid architecture.

Training/prefill uses a parallel associative scan over time (optionally chunked
to bound the materialized (B, C, d_inner, d_state) working set — the TPU-native
adaptation of the paper's CUDA selective-scan kernel: chunk size is picked so
the per-chunk state tensor fits VMEM after TP sharding of d_inner).
Decode carries an explicit (h, conv window) state — O(1) per token, which is
what makes ``long_500k`` run for the hybrid family.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.common import KeyGen, dense_init, zeros


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm or SSMConfig()
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, s.d_state, s.d_conv


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    d = cfg.d_model
    d_inner, dt_rank, d_state, d_conv = _dims(cfg)
    p = {
        "in_proj": dense_init(kg(), d, (2 * d_inner,), dtype),
        "conv_w": (jax.random.normal(kg(), (d_conv, d_inner)) * 0.1).astype(dtype),
        "conv_b": zeros((d_inner,), dtype),
        "x_proj": dense_init(kg(), d_inner, (dt_rank + 2 * d_state,), dtype),
        "dt_proj": dense_init(kg(), dt_rank, (d_inner,), dtype),
        "dt_bias": zeros((d_inner,), dtype),
        # S4D-real init: A_log = log(1..d_state)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                  (d_inner, 1))).astype(dtype),
        "D": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(kg(), d_inner, (d,), dtype,
                               scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }
    return p


def _ssm_inputs(p: Dict, xc: jax.Array, cfg: ModelConfig):
    """xc: (..., d_inner) post-conv activations -> (dt, B, C) selective params."""
    _, dt_rank, d_state, _ = _dims(cfg)
    proj = jnp.einsum("...i,ij->...j", xc, p["x_proj"].astype(xc.dtype))
    dt_in, b, c = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jnp.einsum("...r,ri->...i", dt_in, p["dt_proj"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def _discretize(p: Dict, dt: jax.Array, b: jax.Array, xc: jax.Array):
    """Returns (a_bar, bx): h_t = a_bar_t * h_{t-1} + bx_t, shapes (...,d_in,N)."""
    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (d_in, N)
    a_bar = jnp.exp(dt[..., None] * a)                        # (...,d_in,N)
    bx = dt[..., None] * b[..., None, :] * xc.astype(jnp.float32)[..., None]
    return a_bar, bx


def _causal_conv(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Depthwise causal conv over (B,L,d_inner)."""
    _, _, _, d_conv = _dims(cfg)
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    w = p["conv_w"].astype(x.dtype)                           # (d_conv, d_in)
    y = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(d_conv))
    return y + p["conv_b"].astype(x.dtype)


def _scan_assoc(a_bar: jax.Array, bx: jax.Array,
                h0: jax.Array | None = None):
    """Associative scan over axis=1 (time). Returns h (B,L,d_in,N)."""
    if h0 is not None:
        # fold initial state into the first step
        bx = bx.at[:, 0].add(a_bar[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    return h


def mamba_scan(a_bar: jax.Array, bx: jax.Array, chunk: int = 128,
               h0: jax.Array | None = None):
    """Chunked parallel scan: associative within chunks, lax.scan across.

    a_bar, bx: (B, L, d_in, N). Bounds the materialized scan working set to
    (B, chunk, d_in, N) per chunk — VMEM-friendly after TP shards d_in.
    """
    b_, l, d_in, n = a_bar.shape
    if l <= chunk:
        return _scan_assoc(a_bar, bx, h0)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    a_c = a_bar.reshape(b_, nc, chunk, d_in, n).transpose(1, 0, 2, 3, 4)
    b_c = bx.reshape(b_, nc, chunk, d_in, n).transpose(1, 0, 2, 3, 4)
    h_init = jnp.zeros((b_, d_in, n), jnp.float32) if h0 is None else h0

    def step(carry, xs):
        a_k, b_k = xs                                  # (B, chunk, d_in, N)
        h = _scan_assoc(a_k, b_k, carry)
        return h[:, -1], h

    _, hs = jax.lax.scan(step, h_init, (a_c, b_c))
    return hs.transpose(1, 0, 2, 3, 4).reshape(b_, l, d_in, n)


def mamba_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                  chunk: int = 128) -> jax.Array:
    """x: (B,L,d) -> (B,L,d)."""
    from repro.models.runtime_flags import resolve_chunk
    chunk = resolve_chunk(chunk, x.shape[1])
    d_inner, _, _, _ = _dims(cfg)
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(x.dtype))
    xr, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(p, xr, cfg))
    dt, b, c = _ssm_inputs(p, xc, cfg)
    a_bar, bx = _discretize(p, dt, b, xc)
    h = mamba_scan(a_bar, bx, chunk)                          # (B,L,d_in,N)
    y = jnp.einsum("blin,bln->bli", h, c)
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bli,id->bld", y, p["out_proj"].astype(x.dtype))


def mamba_prefill(p: Dict, x: jax.Array, cfg: ModelConfig,
                  chunk: int = 128) -> Tuple[jax.Array, Dict]:
    """Forward that also emits the decode state (h_final, conv window)."""
    from repro.models.runtime_flags import resolve_chunk
    chunk = resolve_chunk(chunk, x.shape[1])
    d_inner, _, _, d_conv = _dims(cfg)
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(x.dtype))
    xr, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(p, xr, cfg))
    dt, b, c = _ssm_inputs(p, xc, cfg)
    a_bar, bx = _discretize(p, dt, b, xc)
    h = mamba_scan(a_bar, bx, chunk)
    y = jnp.einsum("blin,bln->bli", h, c)
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bli,id->bld", y, p["out_proj"].astype(x.dtype))
    # conv window = last (d_conv-1) pre-activation inputs (pad if short)
    tail = xr[:, -(d_conv - 1):]
    pad = d_conv - 1 - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    state = {"h": h[:, -1], "conv": tail}
    return out, state


# ----------------------------------------------------------------------------
# decode: O(1) recurrent state
# ----------------------------------------------------------------------------

def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_inner, _, d_state, d_conv = _dims(cfg)
    return {
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
    }


def mamba_decode(p: Dict, x: jax.Array, state: Dict,
                 cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    """x: (B,1,d) one token. Returns (y (B,1,d), new_state)."""
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(x.dtype))
    xr, z = jnp.split(xz, 2, axis=-1)                          # (B,1,d_in)
    window = jnp.concatenate([state["conv"], xr[:, 0:1]], axis=1)  # (B,d_conv,d_in)
    w = p["conv_w"].astype(x.dtype)
    xc = jnp.einsum("bki,ki->bi", window, w) + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)[:, None]                              # (B,1,d_in)
    dt, b, c = _ssm_inputs(p, xc, cfg)
    a_bar, bx = _discretize(p, dt, b, xc)                      # (B,1,d_in,N)
    h = a_bar[:, 0] * state["h"] + bx[:, 0]                    # (B,d_in,N)
    y = jnp.einsum("bin,bn->bi", h, c[:, 0])
    y = y + xc[:, 0].astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bli,id->bld", y, p["out_proj"].astype(x.dtype))
    return out, {"h": h, "conv": window[:, 1:]}
