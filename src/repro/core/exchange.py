"""Host-side step scheduling for the exchange mechanisms.

The exchange mechanisms themselves (prediction / checkpoint / pipelined /
shard_map-compressed) are ``ExchangeStrategy`` classes in
``repro.train.engine``; each strategy owns its schedule via
``strategy.plan(step)``. ``StepPlan`` is the value those plans return: a
static host-side decision of which compiled variant to run and whether
communication happens this step (Section 3's "only periodically communicate
predictions, and omit the distillation term otherwise").

``StepPlan.for_step`` is the config-driven convenience used by strategies and
tests; the stale-replica / peer-logits state that used to live here is now
carried on ``CodistState`` (``train.state``) and updated by the strategies'
``post_update`` / ``host_exchange`` hooks.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import CodistConfig


@dataclass(frozen=True)
class StepPlan:
    """Host-side plan for step k (static — selects which jitted fn to call)."""
    distill: bool    # include the distillation term this step
    exchange: bool   # communication happens this step

    @staticmethod
    def for_step(cfg: CodistConfig, step: int) -> "StepPlan":
        if cfg.n_models < 2:
            return StepPlan(False, False)
        if step < cfg.burn_in_steps:
            return StepPlan(False, False)
        on = (step % cfg.period) == 0
        if cfg.mode == "checkpoints":
            # distill EVERY step against the stale replicas; exchange every T
            return StepPlan(True, on)
        # predictions: distill only on exchange steps (Section 3)
        return StepPlan(on, on)
