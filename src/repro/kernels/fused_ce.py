"""Fused cross-entropy Pallas TPU kernel.

The (T, V) logits tensor is the dominant HBM object of LM training with large
vocabularies (Qwen: 152k). The jnp path materializes exp/normalizer
intermediates at full width; this kernel streams vocab TILES through VMEM,
maintaining an online (max, sumexp, true-logit) triple per token row — one
pass over the logits, no (T, V) temporary, MXU-free (pure VPU reduction).

Grid: (T/block_t, V/block_v) with the vocab axis INNERMOST so the per-row
scratch carries across vocab steps ("arbitrary" dimension semantics). The
final vocab step writes loss = m + log(s) - true.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _ce_kernel(labels_ref, logits_ref, loss_ref, m_ref, s_ref, t_ref, *,
               block_v: int, n_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    x = logits_ref[...].astype(jnp.float32)          # (block_t, block_v)
    labels = labels_ref[...]                         # (block_t,)

    # online logsumexp update
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * alpha + jnp.sum(jnp.exp(x - m_new[:, None]),
                                              axis=-1)
    m_ref[...] = m_new

    # accumulate the true logit if the label falls in this vocab tile
    base = j * block_v
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + base
    hit = cols == labels[:, None]
    t_ref[...] = t_ref[...] + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)

    @pl.when(j == n_v - 1)
    def _fin():
        loss_ref[...] = m_ref[...] + jnp.log(s_ref[...]) - t_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fused_cross_entropy(logits: jax.Array, labels: jax.Array,
                        block_t: int = 256, block_v: int = 512,
                        interpret: bool = False) -> jax.Array:
    """Per-token CE. logits (T, V), labels (T,) int32 -> (T,) fp32.

    T % block_t == 0 and V % block_v == 0 (callers pad; configs already pad
    vocab to a multiple of 256).
    """
    t, v = logits.shape
    assert t % block_t == 0 and v % block_v == 0, (t, v, block_t, block_v)
    n_t, n_v = t // block_t, v // block_v
    kernel = functools.partial(_ce_kernel, block_v=block_v, n_v=n_v)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
            pl.BlockSpec((block_t, block_v), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[
            pl_scratch((block_t,)),
            pl_scratch((block_t,)),
            pl_scratch((block_t,)),
        ],
        interpret=interpret,
    )(labels, logits)


def pl_scratch(shape, dtype=jnp.float32):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
