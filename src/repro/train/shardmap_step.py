"""DEPRECATED — the explicit-collective codistillation step is now the
``ShardMapCompressed`` strategy in ``repro.train.engine``.

Rationale (unchanged): the pure-pjit codist step lets XLA place the cross-pod
exchange — fine for raw logits, but compiler-chosen placement defeats
producer-side COMPRESSION (XLA may move the raw logits and compress
afterwards). ``ShardMapCompressed`` pins the schedule by construction: manual
``shard_map`` over ``"pod"``, each pod computes its model's forward, task
loss and the compressed wire locally, and ``jax.lax.all_gather(wire, "pod")``
is the ONLY cross-pod communication — the links carry exactly the compressed
representation (top-k values+indices / bf16 / a token subsample), fulfilling
the paper's Section-3 accounting on TPU topology. It is CLI-reachable as
``--mode codist-shardmap`` on ``repro.launch.train``.
"""
from __future__ import annotations

import warnings

from typing import Any, Callable, Optional

from repro.configs.base import CodistConfig, TrainConfig
from repro.train.engine import ShardMapCompressed, build_train_step

warnings.warn(
    "repro.train.shardmap_step is deprecated: use the ShardMapCompressed "
    "strategy with repro.train.engine.build_train_step "
    "(see docs/exchange_strategies.md)",
    DeprecationWarning, stacklevel=2)

PyTree = Any


def make_codist_shardmap_step(model, codist: CodistConfig, tc: TrainConfig,
                              mesh, trainable: Optional[PyTree] = None
                              ) -> Callable:
    """DEPRECATED: ``build_train_step`` with ``ShardMapCompressed``.

    State/batch layouts are identical to the prediction-exchange step
    (stacked leading n axis over "pod"), so shardings and the host loop are
    unchanged.
    """
    return build_train_step(model, tc, codist,
                            ShardMapCompressed(codist, mesh),
                            trainable).variants["on"]
