"""The fused paged-attention decode kernel and the quantized KV pool.

Four guarantees pinned here:

* kernel/oracle parity: ``paged_attention_decode`` matches the jnp
  gather+dense-softmax oracle across ragged lengths (incl. the length-0
  context edge), GQA/MQA head groupings, and mid-stream slot churn —
  <=1e-4 at fp32 cache dtype (the quantized kernel is compared against the
  quantized oracle at the same bound; quantization ERROR vs fp32 has its
  own documented bound below);
* the structural claim of the fusion, asserted the way
  tests/test_kernel_grads.py pins no-(T,V)-temporary: the fused decode
  jaxpr contains NO ``(S, MB*BS, KVh, hd)`` gather temporary (any
  producer, any dtype), while the jnp path demonstrably does;
* quantize -> scatter -> gather -> dequantize round-trips within the
  per-dtype error bound (int8: half a quantization step =
  ``absmax/254`` per row; fp8 e4m3: half-ULP relative = ``2**-4`` of each
  element);
* the null-block invariant: after arbitrary allocate / free / defrag
  churn, block 0 (and its scale row) stays all-zero and every dead table
  entry aliases it.

All kernels run interpret=True (CPU container).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from dataclasses import replace

from repro.configs import get_reduced
from repro.kernels.paged_attention import (paged_attention_decode,
                                           paged_attention_decode_ref)
from repro.kernels.paged_cache import (is_quantized_dtype, paged_gather_ref,
                                       paged_scatter_quant,
                                       paged_scatter_quant_ref,
                                       quantize_rows)
from repro.models import build_model

TOL = dict(rtol=1e-4, atol=1e-4)
QUANT_DTYPES = [jnp.int8, jnp.float8_e4m3fn]


def _pool_setup(lengths, *, mb=4, nb=32, bs=4, kvh=2, g=2, hd=16, seed=0):
    """Random pools + a disjoint-block table covering ``lengths``."""
    ks = jax.random.split(jax.random.key(seed), 3)
    h = kvh * g
    q = jax.random.normal(ks[0], (len(lengths), h, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, kvh, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, kvh, hd))
    table = np.zeros((len(lengths), mb), np.int32)
    free = list(range(1, nb))
    for s, ln in enumerate(lengths):
        for m in range((ln + bs) // bs):
            table[s, m] = free.pop(0)
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(
        np.asarray(lengths, np.int32))


class TestKernelParity:
    @pytest.mark.parametrize("lengths", [
        [0, 3, 9, 15],          # incl. the length-0-context edge
        [15, 15, 15, 15],       # every block live
        [0, 0],                 # all slots at the edge
        [7],                    # single slot
    ])
    def test_matches_oracle_ragged(self, lengths):
        q, k, v, table, lens = _pool_setup(lengths)
        out = paged_attention_decode(q, k, v, table, lens, interpret=True)
        ref = paged_attention_decode_ref(q, k, v, table, lens)
        np.testing.assert_allclose(out, ref, **TOL)

    @pytest.mark.parametrize("kvh,g", [(1, 4), (2, 1), (2, 4), (4, 2)])
    def test_gqa_head_groupings(self, kvh, g):
        """MQA (kvh=1), MHA (g=1) and grouped layouts all map correctly."""
        q, k, v, table, lens = _pool_setup([2, 6, 11], kvh=kvh, g=g, hd=8)
        out = paged_attention_decode(q, k, v, table, lens, interpret=True)
        ref = paged_attention_decode_ref(q, k, v, table, lens)
        np.testing.assert_allclose(out, ref, **TOL)

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_quantized_pool_matches_quantized_oracle(self, dtype):
        q, k, v, table, lens = _pool_setup([0, 5, 10, 14], seed=2)
        kq, ksc = quantize_rows(k, dtype)
        vq, vsc = quantize_rows(v, dtype)
        out = paged_attention_decode(q, kq, vq, table, lens,
                                     k_scale=ksc, v_scale=vsc,
                                     interpret=True)
        ref = paged_attention_decode_ref(q, kq, vq, table, lens,
                                         k_scale=ksc, v_scale=vsc)
        np.testing.assert_allclose(out, ref, **TOL)

    def test_churn_reuses_blocks_consistently(self):
        """Mid-stream slot churn: append tokens, free a slot, re-admit a
        different-length context into the freed blocks — kernel and oracle
        agree at every step (the table indirection, not block identity,
        defines the context)."""
        rng = np.random.default_rng(0)
        bs, nb, mb, kvh, g, hd = 4, 16, 3, 2, 2, 8
        k_pool = jnp.zeros((nb, bs, kvh, hd))
        v_pool = jnp.zeros((nb, bs, kvh, hd))
        table = np.zeros((2, mb), np.int32)
        table[0, :2] = [3, 5]       # slot 0: blocks 3,5
        table[1, :2] = [5, 3]       # later: slot 1 reuses them REVERSED
        lengths = np.array([6, 0], np.int32)

        def fill(pool, slot, upto):
            for p in range(upto + 1):
                blk, off = table[slot, p // bs], p % bs
                pool = pool.at[blk, off].set(
                    jnp.asarray(rng.normal(size=(kvh, hd)), jnp.float32))
            return pool

        k_pool = fill(k_pool, 0, 6)
        v_pool = fill(v_pool, 0, 6)
        for step, lens in enumerate([np.array([6, 0]), np.array([7, 0]),
                                     np.array([0, 5])]):
            if step == 2:           # slot 0 evicted, slot 1 admitted
                k_pool = fill(k_pool, 1, 5)
                v_pool = fill(v_pool, 1, 5)
            q = jnp.asarray(rng.normal(size=(2, kvh * g, hd)), jnp.float32)
            lens_j = jnp.asarray(lens.astype(np.int32))
            out = paged_attention_decode(q, k_pool, v_pool,
                                         jnp.asarray(table), lens_j,
                                         interpret=True)
            ref = paged_attention_decode_ref(q, k_pool, v_pool,
                                             jnp.asarray(table), lens_j)
            np.testing.assert_allclose(out, ref, err_msg=f"step {step}",
                                       **TOL)

    def test_dead_blocks_contribute_nothing(self):
        """Garbage in never-gathered pool blocks cannot leak into any
        slot's output (only-live-block streaming, null-block aliasing)."""
        q, k, v, table, lens = _pool_setup([3, 6])
        ref = paged_attention_decode(q, k, v, table, lens, interpret=True)
        used = set(np.asarray(table).ravel().tolist()) | {0}
        for b in range(k.shape[0]):
            if b not in used:
                k = k.at[b].set(1e6)
                v = v.at[b].set(-1e6)
        out = paged_attention_decode(q, k, v, table, lens, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ----------------------------------------------------------------------------
# quantization round-trip error bounds (per cache_dtype)
# ----------------------------------------------------------------------------

class TestQuantRoundTrip:
    def _bound(self, x, dtype):
        """Per-element absolute error bound, documented in docs/serving.md:
        int8 -> half a step of the per-row absmax/127 grid; fp8 e4m3 ->
        half-ULP relative error (3 mantissa bits) on each element."""
        absmax = np.max(np.abs(np.asarray(x)), axis=(-2, -1), keepdims=True)
        if jnp.dtype(dtype) == jnp.int8:
            return absmax / 254.0 * 1.001
        return np.abs(np.asarray(x)) * 2.0 ** -4 + absmax * 1e-6

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_quantize_rows_round_trip(self, dtype):
        x = jax.random.normal(jax.random.key(0), (3, 5, 4, 2, 16)) * 3.0
        q, sc = quantize_rows(x, dtype)
        deq = np.asarray(q, np.float32) * np.asarray(sc)[..., None, None]
        err = np.abs(np.asarray(x) - deq)
        assert (err <= self._bound(x, dtype)).all(), float(err.max())

    @pytest.mark.parametrize("dtype", QUANT_DTYPES)
    def test_scatter_gather_round_trip(self, dtype):
        """quantize -> (fused) scatter -> gather -> dequantize: the decode
        append path, end to end through the kernels."""
        nb, bs, kvh, hd, s = 12, 4, 2, 8, 3
        new = jax.random.normal(jax.random.key(1), (s, kvh, hd)) * 2.0
        wslot = np.full((nb,), -1, np.int32)
        woff = np.zeros((nb,), np.int32)
        for slot, (blk, off) in enumerate([(2, 1), (5, 3), (9, 0)]):
            wslot[blk], woff[blk] = slot, off
        pool = jnp.zeros((nb, bs, kvh, hd), dtype)
        scales = jnp.zeros((nb, bs))
        got = paged_scatter_quant(pool, scales, new, jnp.asarray(wslot),
                                  jnp.asarray(woff), interpret=True)
        want = paged_scatter_quant_ref(pool, scales, new, jnp.asarray(wslot),
                                       jnp.asarray(woff))
        np.testing.assert_array_equal(np.asarray(got[0]).view(np.uint8),
                                      np.asarray(want[0]).view(np.uint8))
        # scales agree to the ULP (XLA may compile /qmax as *reciprocal in
        # one context and a true divide in the other)
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                                   rtol=1e-6, atol=0)

        table = jnp.asarray([[2, 0], [5, 0], [9, 0]], jnp.int32)
        n_live = jnp.ones((s,), jnp.int32)
        g = paged_gather_ref(got[0].astype(jnp.float32), table, n_live)
        gs = paged_gather_ref(got[1][..., None, None], table, n_live)
        deq = np.asarray(g * gs)                       # (S, 2*BS, kvh, hd)
        for slot, (blk, off) in enumerate([(2, 1), (5, 3), (9, 0)]):
            x = np.asarray(new[slot])
            err = np.abs(x - deq[slot, off])
            assert (err <= self._bound(x[None], dtype)[0]).all(), \
                (jnp.dtype(dtype).name, float(err.max()))

    def test_zero_rows_are_exact(self):
        """All-zero rows take scale 0 and dequantize to exactly 0 — the
        null block stays exact under quantization."""
        q, sc = quantize_rows(jnp.zeros((4, 2, 8)), jnp.int8)
        assert np.all(np.asarray(sc) == 0.0)
        assert np.all(np.asarray(q) == 0)


# ----------------------------------------------------------------------------
# null-block invariant on the pool, per cache_dtype
# ----------------------------------------------------------------------------

def _tiny_model():
    cfg = replace(get_reduced("qwen1.5-0.5b"), num_layers=2, d_model=64,
                  d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=2,
                  head_dim=32)
    return cfg, build_model(cfg)


class TestNullBlockInvariant:
    def _check(self, pool):
        for sub in pool.kv.values():
            for name, arr in sub.items():
                assert np.all(np.asarray(arr[:, 0]) == 0), \
                    f"null block dirtied in {name}"
        table = pool.table
        for s in range(pool.max_slots):
            n = len(pool.slot_blocks[s])
            assert np.all(table[s, n:] == 0), "dead entry not aliasing null"
            assert 0 not in pool.slot_blocks[s], "null block allocated"
        assert 0 not in pool.free, "null block in the free list"

    @pytest.mark.parametrize("cache_dtype",
                             [jnp.float32, jnp.int8, jnp.float8_e4m3fn])
    def test_alloc_free_defrag_churn(self, cache_dtype):
        from repro.serve.fleet.cache import PagedCachePool
        cfg, model = _tiny_model()
        params = model.init(jax.random.key(0))
        pool = PagedCachePool(model, max_slots=4, block_size=4,
                              num_blocks=32, max_blocks_per_slot=8,
                              cache_dtype=cache_dtype)
        assert pool.quantized == is_quantized_dtype(cache_dtype)
        prefill = jax.jit(
            lambda p, b, cap: model.prefill(
                p, b, cap, cache_dtype=(jnp.float32 if pool.quantized
                                        else cache_dtype)),
            static_argnums=(2,))
        rng = np.random.default_rng(7)
        live = {}
        for step in range(12):
            op = rng.integers(0, 3)
            if op == 0 and len(live) < pool.max_slots:
                slot = next(s for s in range(pool.max_slots)
                            if s not in live)
                length = int(rng.integers(1, 9))
                if not pool.can_admit(length + 4):
                    continue
                pool.allocate(slot, length + 4)
                toks = jnp.asarray(rng.integers(0, 64, size=(1, length)),
                                   jnp.int32)
                _, cache = prefill(params, {"tokens": toks}, length)
                pool.insert_prefill(slot, cache, length)
                live[slot] = length
            elif op == 1 and live:
                slot = sorted(live)[int(rng.integers(0, len(live)))]
                pool.free_slot(slot)
                del live[slot]
            else:
                pool.defrag()
            self._check(pool)


# ----------------------------------------------------------------------------
# structural guarantee: the gather temporary never exists on the fused path
# ----------------------------------------------------------------------------

def _shape_producers(fn, *args, shape):
    """Primitives producing an output of exactly ``shape`` (any dtype) in
    the DCE'd jaxpr — NO allowlist: the fused claim is that the gather
    temporary does not exist at all, not that only data movement makes it."""
    from jax.interpreters import partial_eval as pe
    from tests.test_kernel_grads import _iter_eqns
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr, _ = pe.dce_jaxpr(closed.jaxpr, [True] * len(closed.jaxpr.outvars))
    producers = set()
    for eqn in _iter_eqns(jaxpr):
        for var in eqn.outvars:
            if getattr(var.aval, "shape", None) == shape:
                producers.add(eqn.primitive.name)
    return producers


class TestNoGatherTemporary:
    def _trace_args(self, cache_dtype):
        from repro.serve.fleet.cache import PagedCachePool
        cfg, model = _tiny_model()
        params = model.init(jax.random.key(0))
        S, BS, MB, NB = 4, 4, 4, 16
        pool = PagedCachePool(model, max_slots=S, block_size=BS,
                              num_blocks=NB, max_blocks_per_slot=MB,
                              cache_dtype=cache_dtype)
        args = (params, pool.kv, pool.states,
                jnp.asarray(pool.table), jnp.asarray(pool.lengths),
                jnp.zeros((NB,), jnp.int32) - 1, jnp.zeros((NB,), jnp.int32),
                jnp.zeros((S, 1), jnp.int32))
        gather_shape = (S, MB * BS, cfg.num_kv_heads, cfg.resolved_head_dim)
        return model, args, gather_shape

    @pytest.mark.parametrize("cache_dtype", [jnp.float32, jnp.int8])
    def test_fused_decode_has_no_gather_temporary(self, cache_dtype):
        from repro.serve.fleet.model_exec import build_decode_step
        model, args, shape = self._trace_args(cache_dtype)
        step = build_decode_step(model, fused_attention=True)
        assert _shape_producers(step, *args, shape=shape) == set()

    def test_jnp_path_is_dirty(self):
        """Sanity: the check has teeth — the oracle DOES materialize the
        (S, MB*BS, KVh, hd) gather temporary."""
        from repro.serve.fleet.model_exec import build_decode_step
        model, args, shape = self._trace_args(jnp.float32)
        step = build_decode_step(model, fused_attention=False)
        assert _shape_producers(step, *args, shape=shape) != set()


# ----------------------------------------------------------------------------
# fleet-level: explicit fused flag keeps token parity; quantized fleet runs
# ----------------------------------------------------------------------------

def _drain_fleet(model, params, reqs, cache_dtype, fused):
    from repro.serve.fleet import FleetConfig, FleetEngine
    fc = FleetConfig(max_slots=2, block_size=4, num_blocks=32,
                     max_blocks_per_slot=8, max_prefills_per_step=1,
                     fused_attention=fused)
    eng = FleetEngine(model, params, fc, cache_dtype=cache_dtype)
    for r in reqs:
        eng.enqueue(r)
    eng.drain()
    return eng, {rec.request.rid: rec.tokens for rec in eng.records
                 if not rec.rejected}


def test_fleet_fused_parity_and_quantized_serving():
    from repro.serve import Engine
    from repro.serve.fleet.workload import Request
    cfg, model = _tiny_model()
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(5)
    reqs = [Request(i, i * 1.0,
                    tuple(int(x) for x in rng.integers(0, cfg.padded_vocab,
                                                       size=l)), 4)
            for i, l in enumerate([5, 9, 12, 7, 5])]

    # fused_attention=True explicitly: temp-0 token parity with the dense
    # engine stays green (churn-y: 2 slots, staggered arrivals)
    _, fused_streams = _drain_fleet(model, params, reqs, jnp.float32, True)
    eng = Engine(model, params)
    for r in reqs:
        ref = eng.generate({"tokens": jnp.asarray(r.prompt, jnp.int32)[None]},
                           r.max_new)
        want = np.asarray(ref.tokens[0, r.prompt_len:]).tolist()
        assert fused_streams[r.rid] == want, (r.rid, fused_streams[r.rid],
                                              want)

    # int8 pools: bit-deterministic across runs, everything completes, and
    # the byte accounting includes the per-row fp32 scales
    e1, s1 = _drain_fleet(model, params, reqs, jnp.int8, None)
    e2, s2 = _drain_fleet(model, params, reqs, jnp.int8, None)
    assert s1 == s2 and len(s1) == len(reqs)
    n_attn = len(e1.pool.kv_subs) * e1.pool.n_scan
    per_row = cfg.num_kv_heads * cfg.resolved_head_dim * 1 + 4
    assert e1._kv_bytes_per_token == n_attn * 2 * per_row
