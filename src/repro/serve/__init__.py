from repro.serve.engine import (Engine, GenerationResult,  # noqa: F401
                                default_cache_dtype, resolve_cache_dtype)
